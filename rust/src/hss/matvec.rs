//! Blocked HSS apply — the paper's §4.4 inference operation, batched.
//!
//! `Y = S X + Pᵀ([c0 X0 + U0(R0 X1); c1 X1 + U1(R1 X0)])` recursively, for
//! a row-major column block X of k independent inputs. The tree is walked
//! **once** per batch: leaves run one dense block-multiply, couplings two
//! thin ones, and permutations move whole k-wide rows — so the weight
//! bytes stream through cache once for k inputs instead of k times. The
//! single-vector `matvec_with` is exactly the k = 1 case of the same
//! traversal; there is no separate per-vector code path.
//!
//! The workspace-based variants reuse per-level scratch buffers (widened
//! to the batch) so the request-path apply performs no allocation after
//! warmup.
//!
//! Every dense block in the walk (leaves, couplings, spike SpMM) bottoms
//! out in the runtime-dispatched SIMD kernels of [`crate::linalg::simd`]
//! via the staged `Matrix`/`Csr` apply paths — the batch width k is the
//! contiguous lane axis of every multiply here. The serving projector
//! ([`crate::model::CompressedModel`]) rounds k up to `simd::padded_k`
//! with zero columns before entering the traversal, so on the serving
//! path the walk runs whole lane groups with no scalar tails; the
//! traversal itself is width-agnostic and accepts any k ≥ 1.

use crate::hss::HssNode;
use crate::linalg::Matrix;

impl HssNode {
    /// y = A x (allocating convenience wrapper; the k = 1 batch).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut ws = Workspace::for_node(self);
        let mut y = vec![0.0; self.n()];
        self.matvec_with(x, &mut y, &mut ws);
        y
    }

    /// y = A x using a reusable workspace — the k = 1 case of
    /// [`HssNode::apply_batch`] (no allocation after warmup).
    pub fn matvec_with(&self, x: &[f32], y: &mut [f32], ws: &mut Workspace) {
        self.apply_batch_with(x, y, 1, ws);
    }

    /// Y = A X for a row-major column block of k independent inputs
    /// (X, Y of shape [n, k]; column c is input c). One tree walk serves
    /// the whole batch.
    pub fn apply_batch(&self, x: &Matrix, y: &mut Matrix, ws: &mut Workspace) {
        assert_eq!(x.rows, self.n(), "input block has {} rows, tree n = {}", x.rows, self.n());
        assert_eq!((y.rows, y.cols), (x.rows, x.cols), "output block shape mismatch");
        self.apply_batch_with(&x.data, &mut y.data, x.cols, ws);
    }

    /// Slice form of [`HssNode::apply_batch`]: `x`/`y` are length n·k
    /// row-major [n, k] blocks. This is the only traversal implementation.
    pub fn apply_batch_with(&self, x: &[f32], y: &mut [f32], k: usize, ws: &mut Workspace) {
        assert!(k > 0, "empty batch");
        assert_eq!(x.len(), self.n() * k);
        assert_eq!(y.len(), self.n() * k);
        // one span per traversal entry, never inside apply_rec: the
        // per-branch sparse corrections open their own `spmm` spans,
        // which therefore nest inside this `hss_walk` total
        let _span = crate::obs::Span::enter(crate::obs::Stage::HssWalk);
        ws.ensure(self, k);
        self.apply_rec(x, y, k, &mut ws.levels, &mut ws.stage);
    }

    fn apply_rec(
        &self,
        x: &[f32],
        y: &mut [f32],
        k: usize,
        levels: &mut [LevelBufs],
        stage: &mut Vec<f32>,
    ) {
        match self {
            HssNode::Leaf { d } => {
                d.apply_batch_into_staged(x, y, k, stage);
            }
            HssNode::Branch {
                n,
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
            } => {
                let n0 = n / 2;
                let (buf, rest) = levels
                    .split_first_mut()
                    .expect("workspace depth too small");
                let xp = &mut buf.xp[..n * k];
                let yp = &mut buf.yp[..n * k];
                let t = &mut buf.t[..];

                // (2) permute input down: xp.row(i) = x.row(perm[i])
                perm.apply_cols_into(x, xp, k);

                // (3) recurse into diagonal blocks of the permuted residual
                // (row ranges of a row-major block are contiguous, so the
                // batch splits at the node boundary without copying)
                let (x0, x1) = xp.split_at(n0 * k);
                let (y0, y1) = yp.split_at_mut(n0 * k);
                c0.apply_rec(x0, y0, k, rest, stage);
                c1.apply_rec(x1, y1, k, rest, stage);

                // couplings: Y0 += U0 (R0 X1), Y1 += U1 (R1 X0) — staged
                // so f16-resident factors widen once per block per call
                let t0 = &mut t[..r0.rows * k];
                r0.apply_batch_into_staged(x1, t0, k, stage);
                u0.apply_batch_add_staged(t0, y0, k, stage);
                let t1 = &mut t[..r1.rows * k];
                r1.apply_batch_into_staged(x0, t1, k, stage);
                u1.apply_batch_add_staged(t1, y1, k, stage);

                // (4) inverse-permute up: y.row(perm[i]) = yp.row(i)
                perm.apply_inv_cols_into(yp, y, k);

                // (1)+(5) add the spike contribution in original coordinates
                sparse.spmm_add_staged(x, y, k, stage);
            }
        }
    }

}

/// Per-level scratch buffers; level `i` serves all nodes at depth `i`
/// (siblings run sequentially, so one buffer set per level suffices).
/// Buffers are sized n·k / rank·k for the widest batch seen so far and
/// grow on demand — a k = 1 workspace warmed on the request path widens
/// once when the first batch arrives, then stays allocation-free.
///
/// `stage` is the f16 staging buffer shared by every block of the
/// traversal: each f16-resident leaf / coupling / spike-value run is
/// widened wholesale into it once per visit, so the hot kernels always
/// run their f32 monomorphization. It is sized to the largest single
/// block of the tree (not the whole tree), so the resident-memory halving
/// of f16 serving survives.
#[derive(Default)]
pub struct Workspace {
    levels: Vec<LevelBufs>,
    stage: Vec<f32>,
}

struct LevelBufs {
    xp: Vec<f32>,
    yp: Vec<f32>,
    t: Vec<f32>,
}

impl Workspace {
    /// Workspace sized for single-vector applies over `node`.
    pub fn for_node(node: &HssNode) -> Workspace {
        Workspace::for_node_batch(node, 1)
    }

    /// Workspace pre-sized for batches of `k` columns over `node`.
    pub fn for_node_batch(node: &HssNode, k: usize) -> Workspace {
        let mut ws = Workspace::default();
        ws.ensure(node, k);
        ws
    }

    /// Grow buffers to fit `node` at batch width `k` (idempotent).
    pub fn ensure(&mut self, node: &HssNode, k: usize) {
        let mut dims: Vec<(usize, usize)> = Vec::new(); // (n, max coupling rank) per level
        collect_dims(node, 0, &mut dims);
        for (lvl, (n, r)) in dims.into_iter().enumerate() {
            if self.levels.len() <= lvl {
                self.levels.push(LevelBufs {
                    xp: vec![0.0; n * k],
                    yp: vec![0.0; n * k],
                    t: vec![0.0; r * k],
                });
            } else {
                let b = &mut self.levels[lvl];
                if b.xp.len() < n * k {
                    b.xp.resize(n * k, 0.0);
                    b.yp.resize(n * k, 0.0);
                }
                if b.t.len() < r * k {
                    b.t.resize(r * k, 0.0);
                }
            }
        }
        // pre-size the f16 staging buffer so the request path performs no
        // allocation after warmup (f32-resident trees never touch it)
        if node.weights_dtype() == crate::linalg::Dtype::F16 {
            let need = max_block_len(node);
            if self.stage.len() < need {
                self.stage.resize(need, 0.0);
            }
        }
    }
}

/// Largest single weight block (leaf, coupling factor, or spike-value
/// run) in the tree — the f16 staging buffer's size.
fn max_block_len(node: &HssNode) -> usize {
    match node {
        HssNode::Leaf { d } => d.data.len(),
        HssNode::Branch {
            sparse,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
            ..
        } => sparse
            .nnz()
            .max(u0.data.len())
            .max(r0.data.len())
            .max(u1.data.len())
            .max(r1.data.len())
            .max(max_block_len(c0))
            .max(max_block_len(c1)),
    }
}

/// (n, max coupling rank) per level — shared with the training backward
/// pass (`train::grad::GradWorkspace`) so both directions size their
/// per-level scratch identically.
pub(crate) fn collect_dims(node: &HssNode, level: usize, dims: &mut Vec<(usize, usize)>) {
    if let HssNode::Branch {
        n, u0, u1, c0, c1, ..
    } = node
    {
        let k = u0.cols.max(u1.cols).max(1);
        if dims.len() <= level {
            dims.push((*n, k));
        } else {
            dims[level].0 = dims[level].0.max(*n);
            dims[level].1 = dims[level].1.max(k);
        }
        collect_dims(c0, level + 1, dims);
        collect_dims(c1, level + 1, dims);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hss::build::tests::trained_like;
    use crate::hss::{build, HssOptions};
    use crate::util::proptest::{check, slices_close};
    use crate::util::rng::Rng;

    fn opts(rank: usize, sp: f64, depth: usize, rcm: bool) -> HssOptions {
        HssOptions {
            rank,
            sparsity: sp,
            depth,
            use_rcm: rcm,
            min_leaf: 4,
            rsvd: false,
            ..Default::default()
        }
    }

    #[test]
    fn matvec_equals_reconstruct_times_x() {
        check(10, |rng| {
            let n = 32 + 16 * rng.below(3);
            let a = trained_like(n, rng.next_u64());
            let depth = 1 + rng.below(3);
            let rcm = rng.below(2) == 1;
            let node = build(&a, &opts(8, 0.1, depth, rcm));
            let rec = node.reconstruct();
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let expect = rec.matvec(&x);
            let got = node.matvec(&x);
            slices_close(&got, &expect, 1e-3, 1e-3, "hss matvec")
        });
    }

    #[test]
    fn apply_batch_equals_per_column_matvec() {
        // includes a permuted depth-3 tree and the k = 1 degenerate case
        check(10, |rng| {
            let n = 32 + 16 * rng.below(3);
            let a = trained_like(n, rng.next_u64());
            let node = build(&a, &opts(8, 0.1, 3, true));
            let k = 1 + rng.below(8);
            let mut x = Matrix::zeros(n, k);
            for v in x.data.iter_mut() {
                *v = rng.gaussian_f32();
            }
            let mut y = Matrix::zeros(n, k);
            let mut ws = Workspace::for_node_batch(&node, k);
            node.apply_batch(&x, &mut y, &mut ws);
            for c in 0..k {
                let expect = node.matvec(&x.col(c));
                slices_close(&y.col(c), &expect, 1e-5, 1e-5, "batch col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn workspace_widens_from_single_vector_use() {
        // warm a workspace at k=1, then push a batch through it — ensure()
        // must widen the level buffers instead of slicing out of bounds
        let a = trained_like(64, 21);
        let node = build(&a, &opts(8, 0.1, 3, true));
        let mut ws = Workspace::for_node(&node);
        let x: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0; 64];
        node.matvec_with(&x, &mut y, &mut ws);
        let k = 5;
        let mut xb = Matrix::zeros(64, k);
        for c in 0..k {
            for i in 0..64 {
                xb.set(i, c, x[i]);
            }
        }
        let mut yb = Matrix::zeros(64, k);
        node.apply_batch(&xb, &mut yb, &mut ws);
        for c in 0..k {
            let got: Vec<f32> = (0..64).map(|i| yb.at(i, c)).collect();
            slices_close(&got, &y, 1e-6, 1e-6, "widened col").unwrap();
        }
    }

    #[test]
    fn workspace_reuse_is_consistent() {
        let a = trained_like(64, 9);
        let node = build(&a, &opts(8, 0.1, 3, true));
        let mut ws = Workspace::for_node(&node);
        let mut rng = Rng::new(1);
        let mut first: Option<Vec<f32>> = None;
        let x: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        for _ in 0..3 {
            let mut y = vec![0.0; 64];
            node.matvec_with(&x, &mut y, &mut ws);
            if let Some(f) = &first {
                assert_eq!(&y, f);
            } else {
                first = Some(y);
            }
        }
    }

    #[test]
    fn zero_input_gives_zero() {
        let a = trained_like(32, 10);
        let node = build(&a, &opts(4, 0.2, 2, true));
        let y = node.matvec(&vec![0.0; 32]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn linearity() {
        let a = trained_like(32, 11);
        let node = build(&a, &opts(6, 0.1, 2, false));
        let mut rng = Rng::new(2);
        let x1: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let x2: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let sum: Vec<f32> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let y1 = node.matvec(&x1);
        let y2 = node.matvec(&x2);
        let ysum = node.matvec(&sum);
        let expect: Vec<f32> = y1.iter().zip(&y2).map(|(a, b)| a + b).collect();
        slices_close(&ysum, &expect, 1e-4, 1e-4, "linearity").unwrap();
    }

}
