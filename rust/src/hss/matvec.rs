//! HSS matrix-vector multiply — the paper's §4.4 inference operation.
//!
//! `y = S x + Pᵀ([c0 x0 + U0(R0 x1); c1 x1 + U1(R1 x0)])` recursively.
//! The workspace-based variant reuses per-level scratch buffers so the
//! request-path apply performs no allocation after warmup.

use crate::hss::HssNode;

impl HssNode {
    /// y = A x (allocating convenience wrapper).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut ws = Workspace::for_node(self);
        let mut y = vec![0.0; self.n()];
        self.matvec_with(x, &mut y, &mut ws);
        y
    }

    /// y = A x using a reusable workspace (no allocation after warmup).
    pub fn matvec_with(&self, x: &[f32], y: &mut [f32], ws: &mut Workspace) {
        assert_eq!(x.len(), self.n());
        assert_eq!(y.len(), self.n());
        ws.ensure(self);
        self.apply_rec(x, y, &mut ws.levels);
    }

    fn apply_rec(&self, x: &[f32], y: &mut [f32], levels: &mut [LevelBufs]) {
        match self {
            HssNode::Leaf { d } => {
                d.matvec_into(x, y);
            }
            HssNode::Branch {
                n,
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
            } => {
                let n0 = n / 2;
                let (buf, rest) = levels
                    .split_first_mut()
                    .expect("workspace depth too small");
                let xp = &mut buf.xp[..*n];
                let yp = &mut buf.yp[..*n];
                let t = &mut buf.t[..];

                // (2) permute input down: xp = x[perm]
                perm.apply_into(x, xp);

                // (3) recurse into diagonal blocks of the permuted residual
                let (x0, x1) = xp.split_at(n0);
                let (y0, y1) = yp.split_at_mut(n0);
                c0.apply_rec(x0, y0, rest);
                c1.apply_rec(x1, y1, rest);

                // couplings: y0 += U0 (R0 x1), y1 += U1 (R1 x0)
                let t0 = &mut t[..r0.rows];
                r0.matvec_into(x1, t0);
                u0.matvec_add(t0, y0);
                let t1 = &mut t[..r1.rows];
                r1.matvec_into(x0, t1);
                u1.matvec_add(t1, y1);

                // (4) inverse-permute up: y[perm[i]] = yp[i]
                perm.apply_inv_into(yp, y);

                // (1)+(5) add the spike contribution in original coordinates
                sparse.matvec_add(x, y);
            }
        }
    }

    /// Y = A·X column-wise for a batch of input columns (eval batching).
    pub fn matmat(&self, x_cols: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut ws = Workspace::for_node(self);
        x_cols
            .iter()
            .map(|x| {
                let mut y = vec![0.0; self.n()];
                self.matvec_with(x, &mut y, &mut ws);
                y
            })
            .collect()
    }
}

/// Per-level scratch buffers; level `i` serves all nodes at depth `i`
/// (siblings run sequentially, so one buffer set per level suffices).
#[derive(Default)]
pub struct Workspace {
    levels: Vec<LevelBufs>,
}

struct LevelBufs {
    xp: Vec<f32>,
    yp: Vec<f32>,
    t: Vec<f32>,
}

impl Workspace {
    pub fn for_node(node: &HssNode) -> Workspace {
        let mut ws = Workspace::default();
        ws.ensure(node);
        ws
    }

    /// Grow buffers to fit `node` (idempotent).
    pub fn ensure(&mut self, node: &HssNode) {
        let mut dims: Vec<(usize, usize)> = Vec::new(); // (n, max coupling rank) per level
        collect_dims(node, 0, &mut dims);
        for (lvl, (n, k)) in dims.into_iter().enumerate() {
            if self.levels.len() <= lvl {
                self.levels.push(LevelBufs {
                    xp: vec![0.0; n],
                    yp: vec![0.0; n],
                    t: vec![0.0; k],
                });
            } else {
                let b = &mut self.levels[lvl];
                if b.xp.len() < n {
                    b.xp.resize(n, 0.0);
                    b.yp.resize(n, 0.0);
                }
                if b.t.len() < k {
                    b.t.resize(k, 0.0);
                }
            }
        }
    }
}

/// (n, max coupling rank) per level — shared with the training backward
/// pass (`train::grad::GradWorkspace`) so both directions size their
/// per-level scratch identically.
pub(crate) fn collect_dims(node: &HssNode, level: usize, dims: &mut Vec<(usize, usize)>) {
    if let HssNode::Branch {
        n, u0, u1, c0, c1, ..
    } = node
    {
        let k = u0.cols.max(u1.cols).max(1);
        if dims.len() <= level {
            dims.push((*n, k));
        } else {
            dims[level].0 = dims[level].0.max(*n);
            dims[level].1 = dims[level].1.max(k);
        }
        collect_dims(c0, level + 1, dims);
        collect_dims(c1, level + 1, dims);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hss::build::tests::trained_like;
    use crate::hss::{build, HssOptions};
    use crate::util::proptest::{check, slices_close};
    use crate::util::rng::Rng;

    fn opts(rank: usize, sp: f64, depth: usize, rcm: bool) -> HssOptions {
        HssOptions {
            rank,
            sparsity: sp,
            depth,
            use_rcm: rcm,
            min_leaf: 4,
            rsvd: false,
            ..Default::default()
        }
    }

    #[test]
    fn matvec_equals_reconstruct_times_x() {
        check(10, |rng| {
            let n = 32 + 16 * rng.below(3);
            let a = trained_like(n, rng.next_u64());
            let depth = 1 + rng.below(3);
            let rcm = rng.below(2) == 1;
            let node = build(&a, &opts(8, 0.1, depth, rcm));
            let rec = node.reconstruct();
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let expect = rec.matvec(&x);
            let got = node.matvec(&x);
            slices_close(&got, &expect, 1e-3, 1e-3, "hss matvec")
        });
    }

    #[test]
    fn workspace_reuse_is_consistent() {
        let a = trained_like(64, 9);
        let node = build(&a, &opts(8, 0.1, 3, true));
        let mut ws = Workspace::for_node(&node);
        let mut rng = Rng::new(1);
        let mut first: Option<Vec<f32>> = None;
        let x: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        for _ in 0..3 {
            let mut y = vec![0.0; 64];
            node.matvec_with(&x, &mut y, &mut ws);
            if let Some(f) = &first {
                assert_eq!(&y, f);
            } else {
                first = Some(y);
            }
        }
    }

    #[test]
    fn zero_input_gives_zero() {
        let a = trained_like(32, 10);
        let node = build(&a, &opts(4, 0.2, 2, true));
        let y = node.matvec(&vec![0.0; 32]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn linearity() {
        let a = trained_like(32, 11);
        let node = build(&a, &opts(6, 0.1, 2, false));
        let mut rng = Rng::new(2);
        let x1: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let x2: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let sum: Vec<f32> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let y1 = node.matvec(&x1);
        let y2 = node.matvec(&x2);
        let ysum = node.matvec(&sum);
        let expect: Vec<f32> = y1.iter().zip(&y2).map(|(a, b)| a + b).collect();
        slices_close(&ysum, &expect, 1e-4, 1e-4, "linearity").unwrap();
    }

    #[test]
    fn matmat_matches_column_matvecs() {
        let a = trained_like(32, 12);
        let node = build(&a, &opts(6, 0.1, 2, true));
        let mut rng = Rng::new(3);
        let cols: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..32).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let ys = node.matmat(&cols);
        for (x, y) in cols.iter().zip(&ys) {
            assert_eq!(&node.matvec(x), y);
        }
    }
}
