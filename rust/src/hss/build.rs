//! Recursive sparse-plus-HSS construction (paper Algorithm 1 + §4.5),
//! generalized to arbitrary depth.

use crate::hss::HssNode;
use crate::linalg::rsvd::{randomized_svd, RsvdOptions};
use crate::linalg::svd::truncated_svd;
use crate::linalg::{Matrix, Permutation};
use crate::sparse::graph::Graph;
use crate::sparse::{rcm, top_p_extract, Csr};

/// Construction parameters (mirrors python `hss_np.HssConfig`).
#[derive(Clone, Copy, Debug)]
pub struct HssOptions {
    /// outer rank for the root's off-diagonal blocks (halved per level)
    pub rank: usize,
    /// fraction of entries carved into S (paper's sp/100)
    pub sparsity: f64,
    /// if true, re-extract top-p% at *every* recursion level (§4.5's
    /// literal reading — ablation only: it inflates storage past dense);
    /// default false = one S at the root, matching the paper's storage
    /// numbers ("the percentage ... stored in the separate matrix S")
    pub sparse_per_level: bool,
    /// number of split levels (3 = paper's Algorithm 1)
    pub depth: usize,
    /// singular values below tol are dropped (paper fixes 1e-6)
    pub tol: f32,
    /// apply RCM reordering of the residual (sHSS-RCM vs sHSS)
    pub use_rcm: bool,
    /// stop splitting when a block is smaller than 2*min_leaf
    pub min_leaf: usize,
    /// |residual| quantile that defines the RCM pattern graph
    pub pattern_quantile: f64,
    /// use randomized SVD for the off-diagonal factorizations
    pub rsvd: bool,
    pub rsvd_opts: RsvdOptions,
}

impl Default for HssOptions {
    fn default() -> Self {
        HssOptions {
            rank: 32,
            sparsity: 0.1,
            sparse_per_level: false,
            depth: 3,
            tol: 1e-6,
            use_rcm: true,
            min_leaf: 16,
            pattern_quantile: 0.90,
            rsvd: true,
            rsvd_opts: RsvdOptions::default(),
        }
    }
}

/// Build the sparse-plus-HSS tree for a square matrix.
pub fn build(a: &Matrix, opts: &HssOptions) -> HssNode {
    assert!(a.is_square(), "HSS requires square blocks");
    build_rec(a, opts, opts.depth, opts.rank.max(1), true)
}

fn build_rec(a: &Matrix, opts: &HssOptions, depth: usize, rank: usize, is_root: bool) -> HssNode {
    let n = a.rows;
    if depth == 0 || n / 2 < opts.min_leaf {
        return HssNode::Leaf { d: a.clone() };
    }

    // (1) carve out the spikes (root-only by default; per-level if the
    // §4.5-literal ablation flag is set)
    let p = if is_root || opts.sparse_per_level {
        opts.sparsity
    } else {
        0.0
    };
    let (s_coo, resid) = top_p_extract(a, p);
    let sparse = Csr::from_coo(&s_coo);

    // (2) reorder the residual so big entries hug the diagonal
    let perm = if opts.use_rcm {
        let g = Graph::from_pattern(&resid, opts.pattern_quantile);
        rcm(&g)
    } else {
        Permutation::identity(n)
    };
    let rp = if perm.is_identity() {
        resid
    } else {
        resid.permute_sym(perm.indices())
    };

    // (3) split 2x2, low-rank the off-diagonals, recurse with halved rank
    let n0 = n / 2;
    let a11 = rp.slice(0, n0, 0, n0);
    let a12 = rp.slice(0, n0, n0, n);
    let a21 = rp.slice(n0, n, 0, n0);
    let a22 = rp.slice(n0, n, n0, n);

    let (u0, r0) = factor(&a12, rank, opts);
    let (u1, r1) = factor(&a21, rank, opts);

    let child_rank = (rank / 2).max(1);
    HssNode::Branch {
        n,
        sparse,
        perm,
        u0,
        r0,
        u1,
        r1,
        c0: Box::new(build_rec(&a11, opts, depth - 1, child_rank, false)),
        c1: Box::new(build_rec(&a22, opts, depth - 1, child_rank, false)),
    }
}

fn factor(block: &Matrix, rank: usize, opts: &HssOptions) -> (Matrix, Matrix) {
    if opts.rsvd {
        randomized_svd(block, rank, opts.tol, opts.rsvd_opts)
    } else {
        truncated_svd(block, rank, opts.tol)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::linalg::norms::rel_fro_error;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// Matrix with trained-like structure: low-rank bulk + magnitude spikes.
    pub(crate) fn trained_like(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let u = Matrix::randn(n, 8, seed + 1);
        let v = Matrix::randn(8, n, seed + 2);
        let mut a = u.matmul(&v).scale(0.1);
        for x in a.data.iter_mut() {
            *x += 0.02 * rng.gaussian_f32();
        }
        for _ in 0..3 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += 2.0 * rng.gaussian_f32();
        }
        a
    }

    #[test]
    fn exact_at_full_rank_depth1() {
        let a = trained_like(32, 1);
        let opts = HssOptions {
            rank: 16,
            sparsity: 0.2,
            depth: 1,
            rsvd: false,
            min_leaf: 4,
            ..Default::default()
        };
        let node = build(&a, &opts);
        let err = rel_fro_error(&node.reconstruct(), &a);
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn error_decreases_with_rank() {
        let a = trained_like(64, 2);
        let mut errs = Vec::new();
        for rank in [2, 8, 32] {
            let opts = HssOptions {
                rank,
                sparsity: 0.1,
                depth: 2,
                rsvd: false,
                min_leaf: 4,
                ..Default::default()
            };
            errs.push(rel_fro_error(&build(&a, &opts).reconstruct(), &a));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn error_decreases_with_sparsity() {
        let a = trained_like(64, 3);
        let mut errs = Vec::new();
        for sp in [0.0, 0.1, 0.3] {
            let opts = HssOptions {
                rank: 4,
                sparsity: sp,
                depth: 2,
                rsvd: false,
                min_leaf: 4,
                ..Default::default()
            };
            errs.push(rel_fro_error(&build(&a, &opts).reconstruct(), &a));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn rank_halves_per_level() {
        let a = trained_like(128, 4);
        let opts = HssOptions {
            rank: 16,
            sparsity: 0.05,
            depth: 3,
            min_leaf: 4,
            tol: 0.0,
            rsvd: false,
            ..Default::default()
        };
        let node = build(&a, &opts);
        if let HssNode::Branch { u0, c0, .. } = &node {
            assert_eq!(u0.cols, 16);
            if let HssNode::Branch { u0: cu0, c0: cc0, .. } = c0.as_ref() {
                assert_eq!(cu0.cols, 8);
                if let HssNode::Branch { u0: gu0, .. } = cc0.as_ref() {
                    assert_eq!(gu0.cols, 4);
                } else {
                    panic!("expected depth-3 tree");
                }
            } else {
                panic!("expected branch");
            }
        } else {
            panic!("expected branch");
        }
    }

    #[test]
    fn depth_respects_min_leaf() {
        let a = trained_like(64, 5);
        let opts = HssOptions {
            rank: 8,
            depth: 10, // deeper than possible
            min_leaf: 16,
            ..Default::default()
        };
        let node = build(&a, &opts);
        // leaves must be at least min_leaf = 16, so depth <= 1 (64→32→16)
        assert!(node.depth() <= 2);
        assert!(node.n() == 64);
    }

    #[test]
    fn rcm_does_not_break_reconstruction() {
        check(6, |rng| {
            let n = 32 + 16 * rng.below(3);
            let a = trained_like(n, rng.next_u64());
            for use_rcm in [false, true] {
                let opts = HssOptions {
                    rank: 8,
                    sparsity: 0.1,
                    depth: 2,
                    use_rcm,
                    min_leaf: 4,
                    rsvd: false,
                    ..Default::default()
                };
                let node = build(&a, &opts);
                // reconstruction error is bounded (structure holds); exact
                // value depends on spectrum
                let err = rel_fro_error(&node.reconstruct(), &a);
                if err > 1.0 {
                    return Err(format!("rcm={use_rcm} err {err}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rcm_helps_on_shuffled_banded() {
        // the motivating case: banded structure hidden by a permutation
        let n = 64;
        let mut rng = Rng::new(77);
        let band = Matrix::from_fn(n, n, |i, j| {
            if i.abs_diff(j) <= 4 {
                rng.gaussian_f32()
            } else {
                0.01 * ((i * 31 + j * 17) % 7) as f32 / 7.0
            }
        });
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        let shuffled = band.permute_sym(&p);
        let mk = |use_rcm| HssOptions {
            rank: 6,
            sparsity: 0.0,
            depth: 2,
            use_rcm,
            min_leaf: 4,
            rsvd: false,
            pattern_quantile: 0.85,
            ..Default::default()
        };
        let err_plain = rel_fro_error(&build(&shuffled, &mk(false)).reconstruct(), &shuffled);
        let err_rcm = rel_fro_error(&build(&shuffled, &mk(true)).reconstruct(), &shuffled);
        assert!(
            err_rcm < err_plain,
            "rcm {err_rcm} should beat plain {err_plain} on shuffled banded"
        );
    }
}
