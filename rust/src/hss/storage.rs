//! Storage accounting for the sparse-plus-HSS representation.
//!
//! Matches the paper's "storage" axis: parameters are counted exactly and
//! bytes assume fp16 values. Sparse COO entries pay their index overhead
//! (2×u16 per entry at N ≤ 65536) and each level's permutation costs N·u16.

use crate::hss::HssNode;

/// Bytes per stored value (paper: fp16 end-to-end).
pub const VALUE_BYTES: usize = 2;
/// Bytes per sparse/permutation index (u16 suffices for N ≤ 65536).
pub const INDEX_BYTES: usize = 2;

/// Storage breakdown in parameters and bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Storage {
    /// stored numeric parameters (values only)
    pub params: usize,
    /// total bytes incl. index/permutation overhead at fp16
    pub bytes: usize,
    pub sparse_nnz: usize,
    pub lowrank_params: usize,
    pub leaf_params: usize,
    pub perm_entries: usize,
}

impl Storage {
    fn add(&mut self, other: Storage) {
        self.params += other.params;
        self.bytes += other.bytes;
        self.sparse_nnz += other.sparse_nnz;
        self.lowrank_params += other.lowrank_params;
        self.leaf_params += other.leaf_params;
        self.perm_entries += other.perm_entries;
    }
}

impl HssNode {
    /// Full storage accounting for this tree.
    pub fn storage(&self) -> Storage {
        match self {
            HssNode::Leaf { d } => {
                let params = d.data.len();
                Storage {
                    params,
                    bytes: params * VALUE_BYTES,
                    leaf_params: params,
                    ..Default::default()
                }
            }
            HssNode::Branch {
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
                ..
            } => {
                let nnz = sparse.nnz();
                let lr = u0.data.len() + r0.data.len() + u1.data.len() + r1.data.len();
                let perm_entries = if perm.is_identity() { 0 } else { perm.len() };
                let mut s = Storage {
                    params: nnz + lr,
                    bytes: (nnz + lr) * VALUE_BYTES
                        + nnz * 2 * INDEX_BYTES
                        + perm_entries * INDEX_BYTES,
                    sparse_nnz: nnz,
                    lowrank_params: lr,
                    leaf_params: 0,
                    perm_entries,
                };
                s.add(c0.storage());
                s.add(c1.storage());
                s
            }
        }
    }

    /// Dense baseline bytes for the same matrix at fp16.
    pub fn dense_bytes(&self) -> usize {
        self.n() * self.n() * VALUE_BYTES
    }

    /// Bytes the tree actually keeps resident for its weight values —
    /// leaf blocks, coupling factors, and spike values at their current
    /// dtype. Unlike [`HssNode::storage`] (the format's fp16 accounting),
    /// this reflects in-memory residency: f32-resident trees pay 4 bytes
    /// per value, f16-resident trees 2. Sparse-index and permutation
    /// overhead is excluded (it is dtype-independent; `storage().bytes`
    /// accounts for it).
    pub fn resident_weight_bytes(&self) -> usize {
        match self {
            HssNode::Leaf { d } => d.resident_bytes(),
            HssNode::Branch {
                sparse,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
                ..
            } => {
                sparse.resident_value_bytes()
                    + u0.resident_bytes()
                    + r0.resident_bytes()
                    + u1.resident_bytes()
                    + r1.resident_bytes()
                    + c0.resident_weight_bytes()
                    + c1.resident_weight_bytes()
            }
        }
    }

    /// params(HSS) / params(dense) — the paper's storage axis (stored
    /// values at a common precision). `storage().bytes` additionally
    /// accounts for sparse-index and permutation overhead.
    pub fn storage_ratio(&self) -> f64 {
        self.storage().params as f64 / (self.n() * self.n()) as f64
    }
}

#[cfg(test)]
mod tests {
    use crate::hss::build::tests::trained_like;
    use crate::hss::{build, HssOptions};

    fn opts(rank: usize, sp: f64, depth: usize) -> HssOptions {
        HssOptions {
            rank,
            sparsity: sp,
            depth,
            min_leaf: 4,
            rsvd: false,
            ..Default::default()
        }
    }

    #[test]
    fn leaf_only_matches_dense_params() {
        let a = trained_like(32, 1);
        let node = build(&a, &opts(8, 0.1, 0));
        let s = node.storage();
        assert_eq!(s.params, 32 * 32);
        assert_eq!(s.leaf_params, 32 * 32);
        assert_eq!(s.sparse_nnz, 0);
    }

    #[test]
    fn compresses_at_low_rank() {
        let a = trained_like(128, 2);
        let node = build(&a, &opts(4, 0.05, 3));
        assert!(
            node.storage_ratio() < 1.0,
            "ratio {}",
            node.storage_ratio()
        );
    }

    #[test]
    fn storage_monotone_in_rank() {
        let a = trained_like(64, 3);
        let s1 = build(&a, &opts(2, 0.1, 2)).storage().bytes;
        let s2 = build(&a, &opts(8, 0.1, 2)).storage().bytes;
        assert!(s1 < s2, "{s1} vs {s2}");
    }

    #[test]
    fn storage_monotone_in_sparsity() {
        let a = trained_like(64, 4);
        let s1 = build(&a, &opts(4, 0.05, 2)).storage().bytes;
        let s2 = build(&a, &opts(4, 0.30, 2)).storage().bytes;
        assert!(s1 < s2, "{s1} vs {s2}");
    }

    #[test]
    fn narrowing_halves_resident_weight_bytes() {
        let a = trained_like(64, 6);
        let mut node = build(&a, &opts(4, 0.1, 2));
        let f32_bytes = node.resident_weight_bytes();
        // f32 residency: 4 bytes per stored value, indices excluded
        assert_eq!(f32_bytes, node.storage().params * 4);
        node.narrow_to_f16();
        assert_eq!(node.resident_weight_bytes() * 2, f32_bytes);
        // format accounting is dtype-independent
        assert_eq!(node.storage().params * 2, node.resident_weight_bytes());
    }

    #[test]
    fn breakdown_sums_to_params() {
        let a = trained_like(64, 5);
        let s = build(&a, &opts(8, 0.1, 2)).storage();
        assert_eq!(s.params, s.sparse_nnz + s.lowrank_params + s.leaf_params);
    }
}
