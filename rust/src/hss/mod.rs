//! Hierarchically Semi-Separable (HSS) core — the paper's contribution.
//!
//! A sparse-plus-HSS tree ([`HssNode`]) stores, per recursion level:
//! the level's COO spike matrix S, the RCM permutation P of the residual,
//! low-rank factors U·R of the two off-diagonal blocks (rank halving each
//! level), and recurses into the diagonal blocks until `min_leaf`.
//!
//! `y = A x` follows §4.4/§4.5 of the paper: sparse multiply, permute down,
//! recurse + thin couplings, inverse-permute up — O(N·r) total.

pub mod build;
pub mod matvec;
pub mod node;
pub mod storage;

pub use build::{build, HssOptions};
pub use node::HssNode;
