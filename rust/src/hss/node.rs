//! The sparse-plus-HSS tree node and dense reconstruction (for testing).
//!
//! Leaf and coupling blocks are plain [`Matrix`] values, so the batched
//! traversal ([`crate::hss::matvec`]) applies them through the
//! runtime-dispatched SIMD kernel layer ([`crate::linalg::simd`]) like
//! every other dense multiply in the crate — the tree stores structure,
//! not kernels.

use crate::linalg::{Matrix, Permutation};
use crate::sparse::Csr;

/// One node of the sparse-plus-HSS tree over an n×n block.
#[derive(Clone, Debug)]
pub enum HssNode {
    /// Undecomposed dense diagonal block (recursion floor).
    Leaf { d: Matrix },
    /// Split node: `A ≈ S + Pᵀ [[c0, u0·r0], [u1·r1, c1]] P` where P is the
    /// RCM (or identity) permutation applied to the residual A − S.
    Branch {
        n: usize,
        /// this level's spike matrix, in this node's (pre-permutation) coords
        sparse: Csr,
        /// residual permutation: resid_p = resid[perm][:, perm]
        perm: Permutation,
        /// off-diagonal factors of the permuted residual:
        /// A12 ≈ u0 (n0×k) · r0 (k×n1), A21 ≈ u1 (n1×k) · r1 (k×n0)
        u0: Matrix,
        r0: Matrix,
        u1: Matrix,
        r1: Matrix,
        c0: Box<HssNode>,
        c1: Box<HssNode>,
    },
}

impl HssNode {
    pub fn n(&self) -> usize {
        match self {
            HssNode::Leaf { d } => d.rows,
            HssNode::Branch { n, .. } => *n,
        }
    }

    pub fn depth(&self) -> usize {
        match self {
            HssNode::Leaf { .. } => 0,
            HssNode::Branch { c0, c1, .. } => 1 + c0.depth().max(c1.depth()),
        }
    }

    pub fn num_leaves(&self) -> usize {
        match self {
            HssNode::Leaf { .. } => 1,
            HssNode::Branch { c0, c1, .. } => c0.num_leaves() + c1.num_leaves(),
        }
    }

    /// Structural validation of the whole tree — shape consistency of the
    /// split, coupling factors, permutation, and spike matrix. Used by the
    /// `HSB1` store reader so a corrupt file can never build a tree whose
    /// matvec would index out of bounds.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            HssNode::Leaf { d } => {
                if d.rows != d.cols {
                    return Err(format!("hss leaf not square: {}x{}", d.rows, d.cols));
                }
                Ok(())
            }
            HssNode::Branch {
                n,
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
            } => {
                let n0 = n / 2;
                let n1 = n - n0;
                if sparse.rows != *n || sparse.cols != *n {
                    return Err(format!(
                        "hss branch n={n}: spike matrix is {}x{}",
                        sparse.rows, sparse.cols
                    ));
                }
                sparse.validate()?;
                if perm.len() != *n {
                    return Err(format!(
                        "hss branch n={n}: permutation has {} entries",
                        perm.len()
                    ));
                }
                if u0.rows != n0 || r0.cols != n1 || u0.cols != r0.rows {
                    return Err(format!(
                        "hss branch n={n}: u0 {}x{} r0 {}x{} (want {n0}xk, kx{n1})",
                        u0.rows, u0.cols, r0.rows, r0.cols
                    ));
                }
                if u1.rows != n1 || r1.cols != n0 || u1.cols != r1.rows {
                    return Err(format!(
                        "hss branch n={n}: u1 {}x{} r1 {}x{} (want {n1}xk, kx{n0})",
                        u1.rows, u1.cols, r1.rows, r1.cols
                    ));
                }
                if c0.n() != n0 || c1.n() != n1 {
                    return Err(format!(
                        "hss branch n={n}: children cover {}+{} (want {n0}+{n1})",
                        c0.n(),
                        c1.n()
                    ));
                }
                c0.validate()?;
                c1.validate()
            }
        }
    }

    /// Dense matrix represented by the tree (testing/verification only).
    /// Always f32 — f16-resident factors are widened on the way out.
    pub fn reconstruct(&self) -> Matrix {
        match self {
            HssNode::Leaf { d } => d.widen(),
            HssNode::Branch {
                n,
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
            } => {
                let n0 = n / 2;
                let mut rp = Matrix::zeros(*n, *n);
                rp.set_block(0, 0, &c0.reconstruct());
                rp.set_block(n0, n0, &c1.reconstruct());
                rp.set_block(0, n0, &u0.widen().matmul(&r0.widen()));
                rp.set_block(n0, 0, &u1.widen().matmul(&r1.widen()));
                // undo the symmetric permutation: resid[perm[i], perm[j]] = rp[i, j]
                let inv = perm.inverse();
                let resid = rp.permute_sym(inv.indices());
                sparse.to_dense().add(&resid)
            }
        }
    }

    /// Narrow every resident weight buffer — leaf blocks, coupling
    /// factors, and per-level spike values — to f16 in place (idempotent).
    /// Permutations and sparse indices are untouched.
    pub fn narrow_to_f16(&mut self) {
        match self {
            HssNode::Leaf { d } => d.narrow_to_f16(),
            HssNode::Branch {
                sparse,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
                ..
            } => {
                sparse.narrow_to_f16();
                u0.narrow_to_f16();
                r0.narrow_to_f16();
                u1.narrow_to_f16();
                r1.narrow_to_f16();
                c0.narrow_to_f16();
                c1.narrow_to_f16();
            }
        }
    }

    /// Widen every resident weight buffer back to f32 in place (exact;
    /// idempotent) — required before training the tree.
    pub fn widen_to_f32(&mut self) {
        match self {
            HssNode::Leaf { d } => d.widen_to_f32(),
            HssNode::Branch {
                sparse,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
                ..
            } => {
                sparse.widen_to_f32();
                u0.widen_to_f32();
                r0.widen_to_f32();
                u1.widen_to_f32();
                r1.widen_to_f32();
                c0.widen_to_f32();
                c1.widen_to_f32();
            }
        }
    }

    /// Dtype of the resident weight buffers (read off the first leaf —
    /// narrow/widen keep the whole tree uniform).
    pub fn weights_dtype(&self) -> crate::linalg::Dtype {
        match self {
            HssNode::Leaf { d } => d.dtype(),
            HssNode::Branch { c0, .. } => c0.weights_dtype(),
        }
    }
}
