//! Store bench: cold-start time and on-disk bytes for the HSB1 compressed
//! store vs the dense HWT1 baseline (which must recompress at load).
//!
//! The paper's storage claim only pays off in serving if the compressed
//! artifact is what's on disk: this bench measures (a) recompress-from-dense
//! (the pre-store cold start), (b) HSB1 parse (the store cold start —
//! fp16 factors stay f16-resident), and (c) bytes on disk per format.
//!
//!     cargo bench --bench store_load

mod common;

use hisolo::compress::{compress_model_qkv, Method};
use hisolo::compress::CompressorConfig;
use hisolo::model::weights::{Dtype, Tensor, WeightFile};
use hisolo::store::{StoreFile, StoreWriter};
use hisolo::util::timer::Table;
use std::time::Instant;

fn main() {
    let env = common::load_env(4);
    let projections = env.model.qkv_projections();
    let dir = std::env::temp_dir().join("hisolo_bench_store_load");
    std::fs::create_dir_all(&dir).unwrap();

    // dense HWT1 baseline: the same q/k/v subset at fp16
    let hwt_path = dir.join("qkv_dense.hwt");
    let mut wf = WeightFile::default();
    for (name, w) in &projections {
        wf.push(Tensor {
            name: name.clone(),
            dims: vec![w.rows, w.cols],
            f32_data: w.data.to_vec(),
            i32_data: Vec::new(),
            dtype: Dtype::F16,
        });
    }
    wf.save(&hwt_path).unwrap();
    let hwt_bytes = std::fs::metadata(&hwt_path).unwrap().len();

    let mut t = Table::new(&[
        "method",
        "recompress s",
        "hsb1 cold-load ms",
        "speedup",
        "hsb1 bytes",
        "dense hwt bytes",
        "disk ratio",
    ]);

    for method in [Method::SSvd, Method::SHss, Method::SHssRcm] {
        let cfg = CompressorConfig {
            rank: 32,
            sparsity: 0.3,
            depth: 3,
            ..Default::default()
        };

        // (a) the pre-store cold start: recompress every projection
        let t0 = Instant::now();
        let reports = compress_model_qkv(&projections, method, cfg);
        let recompress_s = t0.elapsed().as_secs_f64();

        // persist as HSB1
        let path = dir.join(format!("qkv_{}.hsb1", method.name()));
        let mut sw = StoreWriter::new();
        for r in &reports {
            sw.push_with_meta(&r.name, &r.compressed, Some(method), r.rel_error);
        }
        let hsb_bytes = sw.finish(&path).unwrap();

        // (b) the store cold start: parse + widen, no factorization; best
        // of a few runs to shake out fs cache noise
        let mut best_ms = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let file = StoreFile::open(&path).unwrap();
            let loaded = file.load_all().unwrap();
            std::hint::black_box(loaded.len());
            best_ms = best_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        }

        t.row(&[
            method.name().to_string(),
            format!("{recompress_s:.3}"),
            format!("{best_ms:.2}"),
            format!("{:.0}x", recompress_s * 1e3 / best_ms),
            hsb_bytes.to_string(),
            hwt_bytes.to_string(),
            format!("{:.3}", hsb_bytes as f64 / hwt_bytes as f64),
        ]);
    }

    t.print();
    println!(
        "\nclaim check: the HSB1 store turns cold start from O(SVD) into O(read),\n\
         and the compressed variants occupy a fraction of the dense fp16 bytes\n\
         on disk (disk ratio < 1)."
    );
}
