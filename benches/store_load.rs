//! Store bench: cold-start time and on-disk bytes for the HSB1/HSB2
//! compressed stores vs the dense HWT1 baseline (which must recompress at
//! load), plus the multi-process page-cache-sharing check for the mmap'd
//! sharded reader.
//!
//! The paper's storage claim only pays off in serving if the compressed
//! artifact is what's on disk — and what's *resident*: this bench measures
//! (a) recompress-from-dense (the pre-store cold start), (b) HSB1 parse
//! (the store cold start — fp16 factors stay f16-resident), (c) bytes on
//! disk per format, and (d) with `--procs N` (default 4), N reader
//! processes loading the same sharded HSB2 variant mmap'd vs buffered:
//! mmap'd readers borrow their factor bytes straight out of one shared
//! page-cache copy, so their summed private RSS stays far below the
//! buffered readers', and their process cold-start skips the read+copy.
//!
//! The `mmap_share_check:` line is the CI gate: PASS requires (1) the
//! mmap'd readers' summed private RSS <= 0.7x buffered, (2) the best
//! mmap process cold-start <= the best buffered one, and (3) serving
//! NLLs bit-identical (`to_bits`) between an mmap-backed and a buffered
//! load of the same variant. `--json <path>` appends a one-line
//! `{"bench":"store_load", ...}` trajectory record with `cold_start_us`,
//! `rss_per_proc_bytes`, and `shard_count`.
//!
//!     cargo bench --bench store_load [-- --procs 4 --json traj.jsonl]

mod common;

use hisolo::compress::CompressorConfig;
use hisolo::compress::{compress_model_qkv, Method};
use hisolo::eval::perplexity::window_nll;
use hisolo::model::weights::{Dtype, Tensor, WeightFile};
use hisolo::model::CompressedModel;
use hisolo::store::{MmapMode, ModelStore, StoreFile, StoreWriter};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::timer::Table;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;
use std::time::Instant;

/// Env marker re-execing this binary as a reader child (value: the
/// `MmapMode`), plus the store dir and variant it should load.
const CHILD_ENV: &str = "HISOLO_STORE_LOAD_CHILD";
const STORE_ENV: &str = "HISOLO_STORE_LOAD_STORE";
const VARIANT_ENV: &str = "HISOLO_STORE_LOAD_VARIANT";

fn main() {
    // child processes short-circuit before touching artifacts
    if let Ok(mode) = std::env::var(CHILD_ENV) {
        run_child(&mode);
    }

    let args = Args::parse(&[]);
    let procs = args.get_usize("procs", 4);

    let env = common::load_env(4);
    let projections = env.model.qkv_projections();
    let dir = std::env::temp_dir().join(format!("hisolo_bench_store_load_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // dense HWT1 baseline: the same q/k/v subset at fp16
    let hwt_path = dir.join("qkv_dense.hwt");
    let mut wf = WeightFile::default();
    for (name, w) in &projections {
        wf.push(Tensor {
            name: name.clone(),
            dims: vec![w.rows, w.cols],
            f32_data: w.data.to_vec(),
            i32_data: Vec::new(),
            dtype: Dtype::F16,
        });
    }
    wf.save(&hwt_path).unwrap();
    let hwt_bytes = std::fs::metadata(&hwt_path).unwrap().len();

    let mut t = Table::new(&[
        "method",
        "recompress s",
        "hsb1 cold-load ms",
        "speedup",
        "hsb1 bytes",
        "dense hwt bytes",
        "disk ratio",
    ]);

    let store = ModelStore::open(dir.join("store"));
    let mut hsb2_bytes = 0u64;
    let mut shard_count = 0usize;
    for method in [Method::SSvd, Method::SHss, Method::SHssRcm] {
        let cfg = CompressorConfig {
            rank: 32,
            sparsity: 0.3,
            depth: 3,
            ..Default::default()
        };

        // (a) the pre-store cold start: recompress every projection
        let t0 = Instant::now();
        let reports = compress_model_qkv(&projections, method, cfg);
        let recompress_s = t0.elapsed().as_secs_f64();

        // persist as HSB1
        let path = dir.join(format!("qkv_{}.hsb1", method.name()));
        let mut sw = StoreWriter::new();
        for r in &reports {
            sw.push_with_meta(&r.name, &r.compressed, Some(method), r.rel_error);
        }
        let hsb_bytes = sw.finish(&path).unwrap();

        // (b) the store cold start: parse + widen, no factorization; best
        // of a few runs to shake out fs cache noise
        let mut best_ms = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let file = StoreFile::open(&path).unwrap();
            let loaded = file.load_all().unwrap();
            std::hint::black_box(loaded.len());
            best_ms = best_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        }

        // the sHSS-RCM variant also goes out in the sharded HSB2 form —
        // what the multi-process share check below reads
        if method == Method::SHssRcm {
            let entries: Vec<hisolo::store::ShardEntry> = reports
                .iter()
                .map(|r| hisolo::store::ShardEntry {
                    name: r.name.clone(),
                    method: Some(method),
                    rel_error: r.rel_error,
                    matrix: &r.compressed,
                })
                .collect();
            hisolo::store::write_sharded(&store.sharded_path("shss-rcm"), &entries, 1).unwrap();
            hsb2_bytes = store.variant_bytes("shss-rcm");
            shard_count = store.open_variant("shss-rcm").unwrap().shard_count();
        }

        t.row(&[
            method.name().to_string(),
            format!("{recompress_s:.3}"),
            format!("{best_ms:.2}"),
            format!("{:.0}x", recompress_s * 1e3 / best_ms),
            hsb_bytes.to_string(),
            hwt_bytes.to_string(),
            format!("{:.3}", hsb_bytes as f64 / hwt_bytes as f64),
        ]);
    }

    t.print();
    println!(
        "\nclaim check: the HSB1 store turns cold start from O(SVD) into O(read),\n\
         and the compressed variants occupy a fraction of the dense fp16 bytes\n\
         on disk (disk ratio < 1). hsb2 (sharded, aligned): {hsb2_bytes} bytes in\n\
         {shard_count} shards."
    );

    // ---- serving bitwise check: mmap-backed vs buffered NLLs ------------
    // same variant, two backings, one tiny forward workload: every NLL
    // must match to the bit (the zero-copy reader changes *where* bytes
    // live, never what they are)
    let bitwise = {
        let base = Arc::clone(&env.model);
        let mmap_file = store.open_variant_with("shss-rcm", MmapMode::Auto).unwrap();
        let buf_file = store
            .open_variant_with("shss-rcm", MmapMode::Buffered)
            .unwrap();
        let cm_mmap = CompressedModel::from_store(base.clone(), &mmap_file).unwrap();
        let cm_buf = CompressedModel::from_store(base, &buf_file).unwrap();
        let mut all = true;
        for w in env.windows.iter().take(2) {
            let (nll_m, t_m) = window_nll(&cm_mmap.forward(w), w);
            let (nll_b, t_b) = window_nll(&cm_buf.forward(w), w);
            all &= t_m == t_b && nll_m.to_bits() == nll_b.to_bits();
        }
        println!(
            "serving backings: mmap={} buffered={} nll_bitwise={all}",
            mmap_file.is_mapped(),
            buf_file.is_mapped()
        );
        all
    };

    // ---- multi-process page-cache share check ---------------------------
    let share = run_share_check(&store, procs);

    let (verdict, pass) = match &share {
        Some(sh) => {
            let rss_ok = sh.mmap_priv_kb as f64 <= 0.7 * sh.buffered_priv_kb as f64;
            let cold_ok = sh.mmap_cold_us <= sh.buffered_cold_us;
            let p = rss_ok && cold_ok && bitwise;
            (
                format!(
                    "procs={procs} shards={shard_count} \
                     priv_rss mmap={}kB buffered={}kB (ratio {:.2}, need <=0.70) \
                     cold_us mmap={} buffered={} bitwise={bitwise} {}",
                    sh.mmap_priv_kb,
                    sh.buffered_priv_kb,
                    sh.mmap_priv_kb as f64 / (sh.buffered_priv_kb.max(1)) as f64,
                    sh.mmap_cold_us,
                    sh.buffered_cold_us,
                    if p { "PASS" } else { "FAIL" }
                ),
                p,
            )
        }
        None => (
            format!("procs={procs} bitwise={bitwise} SKIP (mmap or /proc unavailable)"),
            bitwise,
        ),
    };
    println!("\nmmap_share_check: {verdict}");

    let record = obj(vec![
        ("bench", s("store_load")),
        ("procs", num(procs as f64)),
        ("shard_count", num(shard_count as f64)),
        ("hsb2_bytes", num(hsb2_bytes as f64)),
        (
            "cold_start_us",
            num(share.as_ref().map_or(0.0, |sh| sh.mmap_cold_us as f64)),
        ),
        (
            "buffered_cold_start_us",
            num(share.as_ref().map_or(0.0, |sh| sh.buffered_cold_us as f64)),
        ),
        (
            "rss_per_proc_bytes",
            num(share
                .as_ref()
                .map_or(0.0, |sh| sh.mmap_priv_kb as f64 * 1024.0 / procs.max(1) as f64)),
        ),
        (
            "buffered_rss_per_proc_bytes",
            num(share
                .as_ref()
                .map_or(0.0, |sh| sh.buffered_priv_kb as f64 * 1024.0 / procs.max(1) as f64)),
        ),
        ("nll_bitwise", Json::Bool(bitwise)),
        ("pass", Json::Bool(pass)),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended store_load trajectory line to {}", path.display());
    }
    let _ = std::fs::remove_dir_all(&dir);
    if !pass {
        std::process::exit(1);
    }
}

struct ShareCheck {
    /// summed Private_Clean+Private_Dirty across the N concurrent readers
    mmap_priv_kb: u64,
    buffered_priv_kb: u64,
    /// best process cold-start (open variant + decode every entry)
    mmap_cold_us: u64,
    buffered_cold_us: u64,
}

/// Spawn `procs` reader children per mode against the sharded variant.
/// Children load, report their cold-start, hold their decoded model, then
/// measure private RSS only once *every* sibling holds its mapping (the
/// two-phase stdin handshake) — file pages mapped by one process count as
/// private, by N as shared, so concurrency at measure time is the test.
fn run_share_check(store: &ModelStore, procs: usize) -> Option<ShareCheck> {
    if procs == 0 || !cfg!(target_os = "linux") {
        return None;
    }
    // a mapping must actually be available (HISOLO_MMAP=off → SKIP, not
    // a vacuous mmap-vs-mmap FAIL)
    if !store
        .open_variant_with("shss-rcm", MmapMode::Auto)
        .ok()?
        .is_mapped()
    {
        return None;
    }
    // prime the page cache so both modes measure process-cold, disk-warm
    {
        let f = store
            .open_variant_with("shss-rcm", MmapMode::Buffered)
            .ok()?;
        for name in f.names() {
            std::hint::black_box(f.load(name).ok()?.n());
        }
    }
    let mut out = ShareCheck {
        mmap_priv_kb: 0,
        buffered_priv_kb: 0,
        mmap_cold_us: u64::MAX,
        buffered_cold_us: u64::MAX,
    };
    for mode in ["buffered", "mmap"] {
        let (priv_kb, cold_us) = run_reader_fleet(store, procs, mode)?;
        if mode == "mmap" {
            out.mmap_priv_kb = priv_kb;
            out.mmap_cold_us = cold_us;
        } else {
            out.buffered_priv_kb = priv_kb;
            out.buffered_cold_us = cold_us;
        }
    }
    Some(out)
}

/// One fleet of `procs` children in `mode`; returns (summed private kB,
/// best cold-start µs). Any child failure aborts the check (None).
fn run_reader_fleet(store: &ModelStore, procs: usize, mode: &str) -> Option<(u64, u64)> {
    let exe = std::env::current_exe().ok()?;
    let mut children = Vec::with_capacity(procs);
    for _ in 0..procs {
        let child = std::process::Command::new(&exe)
            .env(CHILD_ENV, mode)
            .env(STORE_ENV, store.dir())
            .env(VARIANT_ENV, "shss-rcm")
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .spawn()
            .ok()?;
        children.push(child)
    }
    let mut pipes: Vec<(std::process::ChildStdin, BufReader<std::process::ChildStdout>)> =
        Vec::with_capacity(procs);
    for c in &mut children {
        let stdin = c.stdin.take()?;
        let stdout = BufReader::new(c.stdout.take()?);
        pipes.push((stdin, stdout));
    }
    // phase 1: every child loaded (all mappings live concurrently)
    let mut cold_best = u64::MAX;
    for (_, stdout) in &mut pipes {
        let mut line = String::new();
        stdout.read_line(&mut line).ok()?;
        let cold_us = field(&line, "cold_us")?;
        cold_best = cold_best.min(cold_us);
        if !line.starts_with("LOADED") {
            return None;
        }
    }
    // phase 2: measure while all siblings hold their load
    for (stdin, _) in &mut pipes {
        stdin.write_all(b"measure\n").ok()?;
    }
    let mut priv_sum = 0u64;
    for (_, stdout) in &mut pipes {
        let mut line = String::new();
        stdout.read_line(&mut line).ok()?;
        if !line.starts_with("READY") {
            return None;
        }
        priv_sum += field(&line, "priv_kb")?;
    }
    // release + reap
    drop(pipes);
    for mut c in children {
        let _ = c.wait();
    }
    Some((priv_sum, cold_best))
}

/// Extract `key=<u64>` from a child report line.
fn field(line: &str, key: &str) -> Option<u64> {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
        .and_then(|v| v.parse().ok())
}

/// Reader child: load the variant in the requested mode, report the
/// cold-start, hold everything decoded, measure private RSS on command,
/// hold until the parent hangs up. Never returns.
fn run_child(mode: &str) -> ! {
    let store_dir = std::env::var(STORE_ENV).expect("child store dir");
    let variant = std::env::var(VARIANT_ENV).expect("child variant");
    let mode = if mode == "buffered" {
        MmapMode::Buffered
    } else {
        MmapMode::Auto
    };
    let store = ModelStore::open(&store_dir);
    let t0 = Instant::now();
    let file = store.open_variant_with(&variant, mode).expect("open variant");
    let mut held = Vec::new();
    for name in file.names() {
        held.push(file.load_native(name).expect("decode entry"));
    }
    let cold_us = t0.elapsed().as_micros() as u64;
    println!("LOADED cold_us={cold_us} mapped={}", file.is_mapped());
    let stdin = std::io::stdin();
    let mut line = String::new();
    stdin.lock().read_line(&mut line).expect("measure command");
    let (rss_kb, priv_kb) = self_rss_kb();
    println!("READY rss_kb={rss_kb} priv_kb={priv_kb} entries={}", held.len());
    // hold the mapping until the parent closes our stdin
    line.clear();
    let _ = stdin.lock().read_line(&mut line);
    std::hint::black_box(held.len());
    std::process::exit(0);
}

/// (VmRSS kB, Private_Clean+Private_Dirty kB) of this process. Private
/// pages are the ones *not* shared with a sibling — the quantity the
/// zero-copy mmap reader is supposed to shrink.
fn self_rss_kb() -> (u64, u64) {
    fn kb(text: &str, key: &str) -> u64 {
        text.lines()
            .filter(|l| l.starts_with(key))
            .filter_map(|l| l.split_whitespace().nth(1))
            .filter_map(|v| v.parse::<u64>().ok())
            .sum()
    }
    let rss = std::fs::read_to_string("/proc/self/status")
        .map(|t| kb(&t, "VmRSS:"))
        .unwrap_or(0);
    let privs = std::fs::read_to_string("/proc/self/smaps_rollup")
        .map(|t| kb(&t, "Private_Clean:") + kb(&t, "Private_Dirty:"))
        .unwrap_or(0);
    (rss, privs)
}
