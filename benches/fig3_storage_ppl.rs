//! Figure 3 reproduction: storage vs perplexity scatter + §5 headline.
//!
//! Sweeps rank × sparsity for the paper's Fig-3 methods (Original, sSVD,
//! sR-SVD, sHSS, sHSS-RCM), prints the scatter sorted by storage, and
//! reports the headline: max storage reduction with PPL on-par (≤ +2%) vs
//! the dense baseline (paper claims up to 1.7× on the targeted params).
//!
//!     cargo bench --bench fig3_storage_ppl

mod common;

use hisolo::compress::{CompressorConfig, Method};
use hisolo::eval::sweep::{sweep, to_csv};
use hisolo::util::timer::Table;

fn main() {
    let env = common::load_env(12);
    let threads = common::threads();

    let ranks = [8usize, 16, 32, 64];
    let sparsities = [0.1, 0.2, 0.3];
    let mut configs = Vec::new();
    for &r in &ranks {
        for &sp in &sparsities {
            configs.push(CompressorConfig {
                rank: r,
                sparsity: sp,
                depth: 3,
                ..Default::default()
            });
        }
    }
    println!(
        "== Figure 3: storage vs PPL ({} methods x {} configs, {} windows, {} threads) ==\n",
        Method::FIG3.len(),
        configs.len(),
        env.windows.len(),
        threads
    );

    let mut points = sweep(&env.model, &Method::FIG3, &configs, &env.windows, threads);
    let dense_ppl = points
        .iter()
        .find(|p| p.method == Method::Dense)
        .map(|p| p.ppl)
        .unwrap();
    points.sort_by(|a, b| a.qkv_bytes.cmp(&b.qkv_bytes));

    let mut t = Table::new(&[
        "method", "rank", "sp", "qkv MB", "qkv ratio", "ppl", "d_ppl",
    ]);
    for p in &points {
        t.row(&[
            p.method.paper_label().to_string(),
            p.rank.to_string(),
            format!("{:.1}", p.sparsity),
            format!("{:.3}", p.qkv_bytes as f64 / 1e6),
            format!("{:.3}", p.qkv_ratio()),
            format!("{:.4}", p.ppl),
            format!("{:+.4}", p.ppl - dense_ppl),
        ]);
    }
    t.print();

    // headline: best qkv reduction with on-par PPL (<= +2% of dense)
    println!("\n== §5 headline ==");
    for m in [Method::SHssRcm, Method::SHss, Method::SSvd, Method::SRsvd] {
        let best = points
            .iter()
            .filter(|p| p.method == m && p.ppl <= dense_ppl * 1.02)
            .min_by(|a, b| a.qkv_bytes.cmp(&b.qkv_bytes));
        match best {
            Some(p) => println!(
                "{:<9} best on-par point: {:.2}x qkv reduction (rank {} sp {:.1}, ppl {:.4} vs dense {:.4})",
                m.paper_label(),
                1.0 / p.qkv_ratio(),
                p.rank,
                p.sparsity,
                p.ppl,
                dense_ppl
            ),
            None => println!("{:<9} no on-par point in grid", m.paper_label()),
        }
    }
    println!("(paper: up to 1.7x storage reduction on the 1.6B targeted params, PPL on-par or better)");

    let csv = to_csv(&points);
    let out = "bench_fig3.csv";
    if std::fs::write(out, &csv).is_ok() {
        println!("\nwrote {out}");
    }
}
