//! §4.4 reproduction: HSS matvec is O(N·r) — one sparse multiply plus thin
//! matmuls — vs the dense O(N²).
//!
//! Sweeps N and reports per-apply latency for dense / sSVD / sHSS(+RCM),
//! with the observed scaling exponent between successive sizes.
//!
//!     cargo bench --bench matvec_scaling

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    println!("== §4.4: matvec scaling, rank = N/8, sp = 0.1, depth 3 ==\n");
    let sizes = [256usize, 512, 1024, 2048];
    let methods = [Method::Dense, Method::SSvd, Method::SHss, Method::SHssRcm];

    let mut results: Vec<Vec<f64>> = vec![Vec::new(); methods.len()];
    let mut t = Table::new(&["N", "method", "matvec", "params", "vs dense"]);
    for &n in &sizes {
        let w = synthetic::trained_like(n, 99);
        let cfg = CompressorConfig {
            rank: n / 8,
            sparsity: 0.1,
            depth: 3,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
        let mut dense_ns = 1.0;
        for (mi, &m) in methods.iter().enumerate() {
            let c = comp.compress(&w, m);
            let mut ws = c.workspace();
            let mut y = vec![0.0f32; n];
            let stats = bench(
                || c.matvec_with(std::hint::black_box(&x), &mut y, &mut ws),
                5,
                Duration::from_millis(300),
                20_000,
            );
            if m == Method::Dense {
                dense_ns = stats.mean_ns;
            }
            results[mi].push(stats.mean_ns);
            t.row(&[
                n.to_string(),
                m.paper_label().to_string(),
                fmt_ns(stats.mean_ns),
                c.params().to_string(),
                format!("{:.2}x", stats.mean_ns / dense_ns),
            ]);
        }
        eprintln!("done N={n}");
    }
    t.print();

    println!("\nobserved scaling exponent (log2 time ratio per size doubling):");
    let mut t2 = Table::new(&["method", "256->512", "512->1024", "1024->2048"]);
    for (mi, &m) in methods.iter().enumerate() {
        let r = &results[mi];
        t2.row(&[
            m.paper_label().to_string(),
            format!("{:.2}", (r[1] / r[0]).log2()),
            format!("{:.2}", (r[2] / r[1]).log2()),
            format!("{:.2}", (r[3] / r[2]).log2()),
        ]);
    }
    t2.print();
    println!(
        "\npaper shape: dense doubles cost ~4x per size doubling (exp ~2);\n\
         hierarchical methods grow markedly slower (exp -> ~1 + rank growth)."
    );
}
