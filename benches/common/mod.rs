//! Shared bench plumbing: artifact/model loading with graceful fallback to
//! a random model when `make artifacts` hasn't run.

use hisolo::data::corpus::Corpus;
use hisolo::data::dataset::windows;
use hisolo::model::{ModelConfig, Transformer, WeightFile};
use hisolo::runtime::ArtifactDir;
use std::path::PathBuf;
use std::sync::Arc;

pub struct BenchEnv {
    pub model: Arc<Transformer>,
    pub windows: Vec<Vec<u32>>,
    pub from_artifacts: bool,
    pub dir: Option<PathBuf>,
}

/// Load the trained artifact model + corpus windows, or fall back to a
/// random model + synthetic tokens so benches always run.
pub fn load_env(n_windows: usize) -> BenchEnv {
    let dir = ArtifactDir::default_path();
    if dir.join("manifest.json").exists() {
        let a = ArtifactDir::load(&dir).expect("manifest parse");
        let wf = WeightFile::load(&dir.join("model.hwt")).expect("weights");
        let model = Transformer::from_weights(&wf, a.model_config).expect("model");
        let corpus = Corpus::load(&dir.join("corpus_test.txt")).expect("corpus");
        let ws = windows(&corpus.tokens, a.model_config.seq_len, n_windows);
        BenchEnv {
            model: Arc::new(model),
            windows: ws,
            from_artifacts: true,
            dir: Some(dir),
        }
    } else {
        eprintln!("WARN: artifacts/ missing — using a random model (run `make artifacts`)");
        let cfg = ModelConfig::default();
        let model = Transformer::random(cfg, 7);
        let toks: Vec<u32> = (0..40_000u32).map(|i| (i * 1103515245 + 12345) % 256).collect();
        let ws = windows(&toks, cfg.seq_len, n_windows);
        BenchEnv {
            model: Arc::new(model),
            windows: ws,
            from_artifacts: false,
            dir: None,
        }
    }
}

pub fn threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}
