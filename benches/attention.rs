//! Batched masked attention evidence: one `attention_batch` call over a
//! stacked [Σt, d] block vs the pre-batching per-window loop (slice each
//! window out, run the scalar `causal_mha_scalar`, copy the result back)
//! at batch widths k ∈ {1, 8, 32} over ragged window lengths, plus the
//! padding-overhead % the default power-of-two bucket edges would incur
//! on this length mix.
//!
//! The k = 32 numbers are appended to the JSON trajectory file via
//! `--json <path>`; the final `attention_check` line is asserted by CI:
//! batched attention must beat the per-window loop at batch width 32.
//!
//! Run: `cargo bench --bench attention [-- --d 256 --heads 8 --t 128]`

use hisolo::coordinator::batcher::{bucket_index, default_bucket_edges};
use hisolo::linalg::simd;
use hisolo::linalg::Matrix;
use hisolo::model::attention::{attention_batch, causal_mha_scalar, AttnWorkspace};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let d = args.get_usize("d", 256);
    let heads = args.get_usize("heads", 8);
    let t_top = args.get_usize("t", 128);
    let budget = Duration::from_millis(args.get_usize("budget-ms", 200) as u64);
    assert!(d % heads == 0, "--d must be divisible by --heads");

    println!("== batched masked attention: d={d} heads={heads} t<= {t_top}, ragged k sweep ==");
    println!("   loop = slice + causal_mha_scalar per window; batched = one attention_batch\n");
    let mut table = Table::new(&[
        "k",
        "per-window loop",
        "attention_batch",
        "speedup",
        "pad overhead",
        "max |diff|",
    ]);

    let mut k32: Option<(f64, f64, f64, f64)> = None; // (loop_ns, batch_ns, speedup, pad)
    for &kw in &[1usize, 8, 32] {
        // ragged lengths: cycle from t_top down to ~t_top/2 so the batch
        // straddles real length variance (and one power-of-two edge)
        let half = (t_top / 2).max(1);
        let lens: Vec<usize> = (0..kw).map(|i| t_top - (i * 13) % half).collect();
        let mut offsets = vec![0usize];
        for &t in &lens {
            offsets.push(offsets[offsets.len() - 1] + t);
        }
        let total = *offsets.last().unwrap();
        let qm = Matrix::randn(total, d, 1);
        let km = Matrix::randn(total, d, 2);
        let vm = Matrix::randn(total, d, 3);

        // per-window loop: the pre-batching serving shape — slice the
        // window out of the stack, run scalar attention, copy back
        let mut out_loop = Matrix::zeros(total, d);
        let loop_stats = bench(
            || {
                for w in 0..kw {
                    let (o0, o1) = (offsets[w], offsets[w + 1]);
                    let qs = qm.slice(o0, o1, 0, d);
                    let ks = km.slice(o0, o1, 0, d);
                    let vs = vm.slice(o0, o1, 0, d);
                    out_loop.set_block(o0, 0, &causal_mha_scalar(&qs, &ks, &vs, heads));
                }
            },
            2,
            budget,
            10_000,
        );

        let mut ws = AttnWorkspace::default();
        let mut out_batch = Matrix::zeros(total, d);
        let batch_stats = bench(
            || {
                attention_batch(
                    std::hint::black_box(&qm),
                    &km,
                    &vm,
                    &offsets,
                    heads,
                    &mut out_batch,
                    &mut ws,
                )
            },
            2,
            budget,
            10_000,
        );

        // sanity: same attention, different kernels
        let mut max_diff = 0.0f32;
        for (a, b) in out_batch.data.iter().zip(out_loop.data.iter()) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 1e-3, "batched attention diverged: {max_diff}");

        // padding overhead of this length mix under the default edges
        let edges = default_bucket_edges();
        let mut by_bucket: Vec<Vec<usize>> = vec![Vec::new(); edges.len() + 1];
        for &t in &lens {
            by_bucket[bucket_index(t, &edges)].push(t);
        }
        let (mut actual, mut padded) = (0usize, 0usize);
        for b in by_bucket.iter().filter(|b| !b.is_empty()) {
            let max_t = *b.iter().max().unwrap();
            actual += b.iter().sum::<usize>();
            padded += max_t * b.len();
        }
        let pad_pct = 100.0 * (1.0 - actual as f64 / padded as f64);

        let speedup = loop_stats.mean_ns / batch_stats.mean_ns;
        table.row(&[
            kw.to_string(),
            fmt_ns(loop_stats.mean_ns),
            fmt_ns(batch_stats.mean_ns),
            format!("{speedup:.2}x"),
            format!("{pad_pct:.1}%"),
            format!("{max_diff:.2e}"),
        ]);
        if kw == 32 {
            k32 = Some((loop_stats.mean_ns, batch_stats.mean_ns, speedup, pad_pct));
        }
    }
    table.print();

    // simd kernel race (CI-asserted): the attention-side kernels — the
    // fused scale+max+exp+normalize softmax row, the layernorm row the
    // fused residual epilogues run, and the whole batched attention call —
    // against their scalar arms. Arms are bit-identical by contract, so
    // the race is pure throughput; PASS requires every scalar/simd time
    // ratio ≥ 0.95 (1.0 minus measurement noise). With no accelerated arm
    // on this host the race would time the same code twice — identity,
    // auto-PASS.
    let best = simd::active_level();
    let mut simd_entries: Vec<(String, Json)> = vec![("level".to_string(), s(best.name()))];
    if best == simd::SimdLevel::Scalar {
        println!("\nsimd_check: level=scalar (no accelerated arm on this host) PASS");
    } else {
        let race = |f: &mut dyn FnMut()| -> f64 {
            let prev = simd::force_level(simd::SimdLevel::Scalar);
            let scalar_ns = bench(|| f(), 2, budget, 10_000).mean_ns;
            simd::force_level(best);
            let simd_ns = bench(|| f(), 2, budget, 10_000).mean_ns;
            simd::force_level(prev);
            scalar_ns / simd_ns
        };

        // softmax over a t_top-long score row (the longest window's inner
        // loop), re-seeded from pre-softmax scores each rep
        let scores: Vec<f32> = (0..t_top).map(|i| -(((i * 31) % 97) as f32) * 0.07).collect();
        let mut p = scores.clone();
        let r_soft = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..64 {
                p.copy_from_slice(std::hint::black_box(&scores));
                (kt.exp_softmax_row)(&mut p, 0.125);
            }
        });

        // layernorm row at width d (the fused residual epilogue's kernel)
        let g = vec![1.0f32; d];
        let beta = vec![0.0f32; d];
        let xrow: Vec<f32> = (0..d).map(|i| ((i * 37) % 19) as f32 * 0.1 - 0.9).collect();
        let mut orow = vec![0.0f32; d];
        let r_ln = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..64 {
                (kt.layernorm_row)(std::hint::black_box(&xrow), &g, &beta, 1e-5, &mut orow);
            }
        });

        // end to end: the whole batched attention call at batch width 32
        let kw = 32usize;
        let half = (t_top / 2).max(1);
        let lens: Vec<usize> = (0..kw).map(|i| t_top - (i * 13) % half).collect();
        let mut offs = vec![0usize];
        for &t in &lens {
            offs.push(offs[offs.len() - 1] + t);
        }
        let total = *offs.last().unwrap();
        let qm = Matrix::randn(total, d, 21);
        let km = Matrix::randn(total, d, 22);
        let vm = Matrix::randn(total, d, 23);
        let mut om = Matrix::zeros(total, d);
        let mut ws = AttnWorkspace::default();
        let r_attn = race(&mut || {
            attention_batch(
                std::hint::black_box(&qm),
                &km,
                &vm,
                &offs,
                heads,
                &mut om,
                &mut ws,
            )
        });

        let mut min_ratio = f64::INFINITY;
        for (name, r) in [
            ("exp_softmax_row", r_soft),
            ("layernorm_row", r_ln),
            ("attention_batch", r_attn),
        ] {
            simd_entries.push((format!("{name}_ratio"), num(r)));
            min_ratio = min_ratio.min(r);
        }
        let verdict = if min_ratio >= 0.95 { "PASS" } else { "FAIL" };
        println!(
            "\nsimd_check: level={} exp_softmax_row={r_soft:.2}x layernorm_row={r_ln:.2}x \
             attention_batch={r_attn:.2}x min_ratio={min_ratio:.2} {verdict}",
            best.name()
        );
    }

    let (loop_ns, batch_ns, speedup, pad_pct) = k32.expect("k = 32 case ran");
    let record = obj(vec![
        ("bench", s("attention")),
        ("d", num(d as f64)),
        ("heads", num(heads as f64)),
        ("t_top", num(t_top as f64)),
        ("attn_k32_loop_ns", num(loop_ns)),
        ("attn_k32_batch_ns", num(batch_ns)),
        ("attn_k32_speedup", num(speedup)),
        ("pad_overhead_pct", num(pad_pct)),
        ("simd", Json::Obj(simd_entries.into_iter().collect())),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended attention trajectory line to {}", path.display());
    }

    // CI-asserted: one attention_batch call must beat the per-window loop
    // at batch width 32 (padding overhead reported alongside)
    let verdict = if speedup > 1.0 { "PASS" } else { "FAIL" };
    println!(
        "attention_check: k=32 batched {} vs loop {} speedup={speedup:.2}x pad_overhead={pad_pct:.1}% {verdict}",
        fmt_ns(batch_ns),
        fmt_ns(loop_ns)
    );
}
