//! Batched masked attention evidence: one `attention_batch` call over a
//! stacked [Σt, d] block vs the pre-batching per-window loop (slice each
//! window out, run the scalar `causal_mha_scalar`, copy the result back)
//! at batch widths k ∈ {1, 8, 32} over ragged window lengths, plus the
//! padding-overhead % the default power-of-two bucket edges would incur
//! on this length mix.
//!
//! The k = 32 numbers are appended to the JSON trajectory file via
//! `--json <path>`; the final `attention_check` line is asserted by CI:
//! batched attention must beat the per-window loop at batch width 32.
//!
//! Run: `cargo bench --bench attention [-- --d 256 --heads 8 --t 128]`

use hisolo::coordinator::batcher::{bucket_index, default_bucket_edges};
use hisolo::linalg::Matrix;
use hisolo::model::attention::{attention_batch, causal_mha_scalar, AttnWorkspace};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s};
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let d = args.get_usize("d", 256);
    let heads = args.get_usize("heads", 8);
    let t_top = args.get_usize("t", 128);
    let budget = Duration::from_millis(args.get_usize("budget-ms", 200) as u64);
    assert!(d % heads == 0, "--d must be divisible by --heads");

    println!("== batched masked attention: d={d} heads={heads} t<= {t_top}, ragged k sweep ==");
    println!("   loop = slice + causal_mha_scalar per window; batched = one attention_batch\n");
    let mut table = Table::new(&[
        "k",
        "per-window loop",
        "attention_batch",
        "speedup",
        "pad overhead",
        "max |diff|",
    ]);

    let mut k32: Option<(f64, f64, f64, f64)> = None; // (loop_ns, batch_ns, speedup, pad)
    for &kw in &[1usize, 8, 32] {
        // ragged lengths: cycle from t_top down to ~t_top/2 so the batch
        // straddles real length variance (and one power-of-two edge)
        let half = (t_top / 2).max(1);
        let lens: Vec<usize> = (0..kw).map(|i| t_top - (i * 13) % half).collect();
        let mut offsets = vec![0usize];
        for &t in &lens {
            offsets.push(offsets[offsets.len() - 1] + t);
        }
        let total = *offsets.last().unwrap();
        let qm = Matrix::randn(total, d, 1);
        let km = Matrix::randn(total, d, 2);
        let vm = Matrix::randn(total, d, 3);

        // per-window loop: the pre-batching serving shape — slice the
        // window out of the stack, run scalar attention, copy back
        let mut out_loop = Matrix::zeros(total, d);
        let loop_stats = bench(
            || {
                for w in 0..kw {
                    let (o0, o1) = (offsets[w], offsets[w + 1]);
                    let qs = qm.slice(o0, o1, 0, d);
                    let ks = km.slice(o0, o1, 0, d);
                    let vs = vm.slice(o0, o1, 0, d);
                    out_loop.set_block(o0, 0, &causal_mha_scalar(&qs, &ks, &vs, heads));
                }
            },
            2,
            budget,
            10_000,
        );

        let mut ws = AttnWorkspace::default();
        let mut out_batch = Matrix::zeros(total, d);
        let batch_stats = bench(
            || {
                attention_batch(
                    std::hint::black_box(&qm),
                    &km,
                    &vm,
                    &offsets,
                    heads,
                    &mut out_batch,
                    &mut ws,
                )
            },
            2,
            budget,
            10_000,
        );

        // sanity: same attention, different kernels
        let mut max_diff = 0.0f32;
        for (a, b) in out_batch.data.iter().zip(out_loop.data.iter()) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 1e-3, "batched attention diverged: {max_diff}");

        // padding overhead of this length mix under the default edges
        let edges = default_bucket_edges();
        let mut by_bucket: Vec<Vec<usize>> = vec![Vec::new(); edges.len() + 1];
        for &t in &lens {
            by_bucket[bucket_index(t, &edges)].push(t);
        }
        let (mut actual, mut padded) = (0usize, 0usize);
        for b in by_bucket.iter().filter(|b| !b.is_empty()) {
            let max_t = *b.iter().max().unwrap();
            actual += b.iter().sum::<usize>();
            padded += max_t * b.len();
        }
        let pad_pct = 100.0 * (1.0 - actual as f64 / padded as f64);

        let speedup = loop_stats.mean_ns / batch_stats.mean_ns;
        table.row(&[
            kw.to_string(),
            fmt_ns(loop_stats.mean_ns),
            fmt_ns(batch_stats.mean_ns),
            format!("{speedup:.2}x"),
            format!("{pad_pct:.1}%"),
            format!("{max_diff:.2e}"),
        ]);
        if kw == 32 {
            k32 = Some((loop_stats.mean_ns, batch_stats.mean_ns, speedup, pad_pct));
        }
    }
    table.print();

    let (loop_ns, batch_ns, speedup, pad_pct) = k32.expect("k = 32 case ran");
    let record = obj(vec![
        ("bench", s("attention")),
        ("d", num(d as f64)),
        ("heads", num(heads as f64)),
        ("t_top", num(t_top as f64)),
        ("attn_k32_loop_ns", num(loop_ns)),
        ("attn_k32_batch_ns", num(batch_ns)),
        ("attn_k32_speedup", num(speedup)),
        ("pad_overhead_pct", num(pad_pct)),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended attention trajectory line to {}", path.display());
    }

    // CI-asserted: one attention_batch call must beat the per-window loop
    // at batch width 32 (padding overhead reported alongside)
    let verdict = if speedup > 1.0 { "PASS" } else { "FAIL" };
    println!(
        "attention_check: k=32 batched {} vs loop {} speedup={speedup:.2}x pad_overhead={pad_pct:.1}% {verdict}",
        fmt_ns(batch_ns),
        fmt_ns(loop_ns)
    );
}
