//! §Conclusion claim: "a GPU-friendly implementation keeps compression time
//! within minutes on a single H100" (for 1.6B targeted params).
//!
//! Measures wall-clock compression time per method per matrix size, and the
//! whole-model (all q/k/v projections) pipeline time at our scale.
//!
//!     cargo bench --bench compress_time

mod common;

use hisolo::compress::{compress_model_qkv, Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::util::timer::Table;
use std::time::Instant;

fn main() {
    println!("== compression wall-time per matrix ==\n");
    let methods = [
        Method::Svd,
        Method::Rsvd,
        Method::SSvd,
        Method::SRsvd,
        Method::SHss,
        Method::SHssRcm,
    ];
    let mut t = Table::new(&["N", "method", "seconds"]);
    for &n in &[256usize, 512] {
        let w = synthetic::trained_like(n, 3);
        let cfg = CompressorConfig {
            rank: n / 8,
            sparsity: 0.3,
            depth: 3,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        for &m in &methods {
            let t0 = Instant::now();
            let c = comp.compress(&w, m);
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(c.params());
            t.row(&[n.to_string(), m.paper_label().to_string(), format!("{dt:.3}")]);
        }
        eprintln!("done N={n}");
    }
    t.print();

    println!("\n== whole-model pipeline (all q/k/v projections) ==\n");
    let env = common::load_env(1);
    let projections = env.model.qkv_projections();
    let cfg = CompressorConfig {
        rank: 32,
        sparsity: 0.3,
        depth: 3,
        ..Default::default()
    };
    let mut t2 = Table::new(&["method", "projections", "params in", "seconds"]);
    let params_in: usize = projections.iter().map(|(_, m)| m.data.len()).sum();
    for m in [Method::SSvd, Method::SRsvd, Method::SHss, Method::SHssRcm] {
        let t0 = Instant::now();
        let reports = compress_model_qkv(&projections, m, cfg);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(reports.len());
        t2.row(&[
            m.paper_label().to_string(),
            projections.len().to_string(),
            params_in.to_string(),
            format!("{dt:.2}"),
        ]);
        eprintln!("done {}", m.paper_label());
    }
    t2.print();
    println!(
        "\npaper claim at 1.6B params: minutes on an H100. Scaled to our\n\
         {params_in} params on CPU, whole-model compression should land in\n\
         seconds — same order after the ~2000x parameter scale-down."
    );
}
