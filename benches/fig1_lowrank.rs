//! Figure 1 reproduction: low-rankness of off-diagonal blocks.
//!
//! The paper motivates HSS by showing attention interacts weakly with
//! far-away tokens, making off-diagonal blocks numerically low-rank. We
//! measure the singular-value decay of (a) the off-diagonal blocks of the
//! trained W_Q/W_K/W_V projections and (b) an actual attention-score matrix
//! QKᵀ from a corpus window, and compare against the diagonal blocks.
//!
//!     cargo bench --bench fig1_lowrank

mod common;

use hisolo::linalg::svd::svd;
use hisolo::linalg::Matrix;
use hisolo::util::timer::Table;

fn effective_rank(s: &[f32], frac: f32) -> usize {
    let s0 = s.first().copied().unwrap_or(0.0);
    s.iter().filter(|&&x| x > frac * s0).count()
}

fn sv_series(m: &Matrix, k: usize) -> (Vec<f32>, usize) {
    let f = svd(m);
    let s0 = f.s.first().copied().unwrap_or(1.0).max(1e-30);
    let series: Vec<f32> = f.s.iter().take(k).map(|&x| x / s0).collect();
    let er = effective_rank(&f.s, 0.01);
    (series, er)
}

fn main() {
    let env = common::load_env(2);
    let model = &env.model;
    let n = model.cfg.d_model;
    let half = n / 2;

    println!("== Figure 1: singular-value decay (normalized sigma_i / sigma_1) ==\n");
    let mut t = Table::new(&[
        "matrix", "block", "s8", "s16", "s32", "eff rank (1%)", "of n",
    ]);

    for (name, w) in model.qkv_projections().into_iter().take(3) {
        let a = w.transpose();
        for (block_name, block) in [
            ("off-diag (1,2)", a.slice(0, half, half, n)),
            ("off-diag (2,1)", a.slice(half, n, 0, half)),
            ("diag (1,1)", a.slice(0, half, 0, half)),
        ] {
            let (s, er) = sv_series(&block, 33);
            t.row(&[
                name.clone(),
                block_name.to_string(),
                format!("{:.3}", s.get(8).copied().unwrap_or(0.0)),
                format!("{:.3}", s.get(16).copied().unwrap_or(0.0)),
                format!("{:.3}", s.get(32).copied().unwrap_or(0.0)),
                er.to_string(),
                half.to_string(),
            ]);
        }
    }

    // actual attention scores QK^T on a real window (first layer, head 0)
    let w0 = &env.windows[0];
    let tokens = &w0[..model.cfg.seq_len];
    let tlen = tokens.len();
    // embed + ln + project with layer-0 weights
    let mut h = Matrix::zeros(tlen, n);
    for (i, &tok) in tokens.iter().enumerate() {
        let te = model.tok_emb.row(tok as usize);
        let pe = model.pos_emb.row(i);
        for j in 0..n {
            h.set(i, j, te[j] + pe[j]);
        }
    }
    let l0 = &model.layers[0];
    let a = hisolo::model::transformer::layernorm(&h, &l0.ln1_g, &l0.ln1_b);
    let q = a.matmul(&l0.wq);
    let k = a.matmul(&l0.wk);
    let scores = {
        let mut s = Matrix::zeros(tlen, tlen);
        q.matmul_bt_into(&k, &mut s);
        s
    };
    let th = tlen / 2;
    for (bn, block) in [
        ("QK^T off-diag (2,1)", scores.slice(th, tlen, 0, th)),
        ("QK^T diag (1,1)", scores.slice(0, th, 0, th)),
    ] {
        let (s, er) = sv_series(&block, 33);
        t.row(&[
            "attention".to_string(),
            bn.to_string(),
            format!("{:.3}", s.get(8).copied().unwrap_or(0.0)),
            format!("{:.3}", s.get(16).copied().unwrap_or(0.0)),
            format!("{:.3}", s.get(32).copied().unwrap_or(0.0)),
            er.to_string(),
            th.to_string(),
        ]);
    }
    t.print();

    println!(
        "\npaper's claim reproduced if off-diagonal blocks decay faster\n\
         (smaller eff rank) than diagonal blocks — the compression headroom\n\
         sHSS exploits. Source: {}",
        if env.from_artifacts {
            "trained artifact model"
        } else {
            "random fallback model (run `make artifacts`)"
        }
    );
}
