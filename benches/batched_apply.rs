//! Batched apply engine evidence: per-variant throughput of one
//! `apply_batch` traversal vs a per-vector `matvec_with` loop at
//! k ∈ {1, 8, 32, 128}, plus rows/s for the batched calibration step
//! (one `apply_batch` + one rank-k `accumulate_grad` + Adam).
//!
//! The k = 32 numbers are emitted as a single JSON line (the bench
//! trajectory record); `--json <path>` appends it to a file.
//!
//! Run: `cargo bench --bench batched_apply [-- --n 1024 --json traj.jsonl]`

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::linalg::Matrix;
use hisolo::train::{accumulate_grad, num_params, GradWorkspace, Optimizer, OptimizerKind};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::rng::Rng;
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let n = args.get_usize("n", 1024);
    let rank = args.get_usize("rank", n / 8);
    let budget = Duration::from_millis(args.get_usize("budget-ms", 300) as u64);
    let ks = [1usize, 8, 32, 128];

    let w = synthetic::trained_like(n, 99);
    let comp = Compressor::new(CompressorConfig {
        rank,
        sparsity: 0.1,
        depth: 3,
        ..Default::default()
    });

    println!("== batched apply engine: n={n} rank={rank} depth=3 ==");
    println!("   per-vector loop = k × matvec_with; batched = one apply_batch traversal\n");
    let mut table = Table::new(&[
        "variant",
        "k",
        "matvec loop",
        "apply_batch",
        "speedup",
        "cols/s batched",
    ]);

    let cases: [(&str, Method); 4] = [
        ("dense", Method::Dense),
        ("lowrank (svd)", Method::Svd),
        ("lowrank+csr (ssvd)", Method::SSvd),
        ("shss-rcm", Method::SHssRcm),
    ];
    let mut k32_entries: Vec<(String, Json)> = Vec::new();

    for (label, m) in cases {
        let c = comp.compress(&w, m);
        for &k in &ks {
            let x = Matrix::randn(n, k, 7 + k as u64);
            let cols: Vec<Vec<f32>> = (0..k).map(|c| x.col(c)).collect();

            let mut ws1 = c.workspace();
            let mut y1 = vec![0.0f32; n];
            let loop_stats = bench(
                || {
                    for col in &cols {
                        c.matvec_with(std::hint::black_box(col), &mut y1, &mut ws1);
                    }
                },
                2,
                budget,
                10_000,
            );

            let mut ws = c.workspace_for(k);
            let mut y = Matrix::zeros(n, k);
            let batch_stats = bench(
                || c.apply_batch(std::hint::black_box(&x), &mut y, &mut ws),
                2,
                budget,
                10_000,
            );

            let speedup = loop_stats.mean_ns / batch_stats.mean_ns;
            let cols_per_s = k as f64 * 1e9 / batch_stats.mean_ns;
            table.row(&[
                label.to_string(),
                k.to_string(),
                fmt_ns(loop_stats.mean_ns),
                fmt_ns(batch_stats.mean_ns),
                format!("{speedup:.2}x"),
                format!("{cols_per_s:.0}"),
            ]);
            if k == 32 {
                k32_entries.push((
                    m.name().to_string(),
                    obj(vec![
                        ("loop_ns", num(loop_stats.mean_ns)),
                        ("batch_ns", num(batch_stats.mean_ns)),
                        ("speedup", num(speedup)),
                    ]),
                ));
            }
        }
    }
    table.print();

    // batched calibration step: one apply_batch + rank-k accumulate_grad
    // + Adam on the sHSS-RCM student, reported as rows (samples) per sec
    let batch = 32;
    let mut student = comp.compress(&w, Method::SHssRcm);
    let mut rng = Rng::new(5);
    let mut xb = Matrix::zeros(n, batch);
    rng.fill_gaussian(&mut xb.data);
    let targets: Vec<Vec<f32>> = (0..batch).map(|c| w.matvec(&xb.col(c))).collect();
    let tb = Matrix::from_cols(&targets);
    let mut gb = Matrix::zeros(n, batch);
    let mut grad = vec![0.0f32; num_params(&student)];
    let mut gws = GradWorkspace::for_matrix_batch(&student, batch);
    let mut ws = student.workspace_for(batch);
    let mut opt = OptimizerKind::Adam.build();
    let cal_stats = bench(
        || {
            grad.fill(0.0);
            student.apply_batch(&xb, &mut gb, &mut ws);
            for (g, &t) in gb.data.iter_mut().zip(&tb.data) {
                *g -= t;
            }
            accumulate_grad(&student, &xb, &gb, &mut grad, &mut gws);
            let inv = 1.0 / batch as f32;
            for g in grad.iter_mut() {
                *g *= inv;
            }
            opt.step(&mut student, &grad, 1e-3);
        },
        2,
        budget,
        10_000,
    );
    let rows_per_s = batch as f64 * 1e9 / cal_stats.mean_ns;
    println!(
        "\nbatched calibration step (shss-rcm, batch={batch}): {} per step, {rows_per_s:.0} rows/s",
        fmt_ns(cal_stats.mean_ns)
    );

    // one-line JSON trajectory record (k = 32 per-variant + calibration)
    let record = obj(vec![
        ("bench", s("batched_apply")),
        ("n", num(n as f64)),
        ("rank", num(rank as f64)),
        (
            "k32",
            Json::Obj(k32_entries.into_iter().collect()),
        ),
        ("calib_batch", num(batch as f64)),
        ("calib_rows_per_s", num(rows_per_s)),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended k=32 trajectory line to {}", path.display());
    }
}
