//! Batched apply engine evidence: per-variant throughput of one
//! `apply_batch` traversal vs a per-vector `matvec_with` loop, swept over
//! dtype ∈ {f32, f16} × k ∈ {1, 32}, plus rows/s for the batched
//! calibration step (one `apply_batch` + one rank-k `accumulate_grad` +
//! Adam).
//!
//! The f16 rows run the same kernels on f16-resident factors (widened
//! lane-by-lane in-register), so the table shows what halving resident
//! weight bytes costs — or wins — in throughput. The k = 32 numbers and
//! resident bytes are emitted as a single JSON line (the bench trajectory
//! record); `--json <path>` appends it to a file. The final
//! `f16_resident_check` line is asserted by CI: f16 resident weight bytes
//! must be under 60% of f32 for the HSS variant.
//!
//! Run: `cargo bench --bench batched_apply [-- --n 1024 --json traj.jsonl]`

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::linalg::simd;
use hisolo::linalg::Matrix;
use hisolo::train::{accumulate_grad, num_params, GradWorkspace, Optimizer, OptimizerKind};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::rng::Rng;
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let n = args.get_usize("n", 1024);
    let rank = args.get_usize("rank", n / 8);
    let budget = Duration::from_millis(args.get_usize("budget-ms", 300) as u64);
    let ks = [1usize, 32];

    let w = synthetic::trained_like(n, 99);
    let comp = Compressor::new(CompressorConfig {
        rank,
        sparsity: 0.1,
        depth: 3,
        ..Default::default()
    });

    println!("== batched apply engine: n={n} rank={rank} depth=3, dtype x k sweep ==");
    println!("   per-vector loop = k × matvec_with; batched = one apply_batch traversal\n");
    let mut table = Table::new(&[
        "variant",
        "dtype",
        "k",
        "matvec loop",
        "apply_batch",
        "speedup",
        "cols/s batched",
        "resident bytes",
    ]);

    let cases: [(&str, Method); 4] = [
        ("dense", Method::Dense),
        ("lowrank (svd)", Method::Svd),
        ("lowrank+csr (ssvd)", Method::SSvd),
        ("shss-rcm", Method::SHssRcm),
    ];
    let mut k32_entries: Vec<(String, Json)> = Vec::new();
    // (f32 resident, f16 resident, f32 batch_ns, f16 batch_ns) for shss-rcm
    let mut hss_check: Option<(usize, usize, f64, f64)> = None;

    for (label, m) in cases {
        let c32 = comp.compress(&w, m);
        let mut c16 = c32.clone_shallow();
        c16.narrow_to_f16();
        let mut k32_ns = [0.0f64; 2]; // [f32, f16] batch_ns at k = 32
        for (di, c) in [&c32, &c16].into_iter().enumerate() {
            let dtype = c.weights_dtype();
            let resident = c.resident_weight_bytes();
            for &k in &ks {
                let x = Matrix::randn(n, k, 7 + k as u64);
                let cols: Vec<Vec<f32>> = (0..k).map(|c| x.col(c)).collect();

                let mut ws1 = c.workspace();
                let mut y1 = vec![0.0f32; n];
                let loop_stats = bench(
                    || {
                        for col in &cols {
                            c.matvec_with(std::hint::black_box(col), &mut y1, &mut ws1);
                        }
                    },
                    2,
                    budget,
                    10_000,
                );

                let mut ws = c.workspace_for(k);
                let mut y = Matrix::zeros(n, k);
                let batch_stats = bench(
                    || c.apply_batch(std::hint::black_box(&x), &mut y, &mut ws),
                    2,
                    budget,
                    10_000,
                );

                let speedup = loop_stats.mean_ns / batch_stats.mean_ns;
                let cols_per_s = k as f64 * 1e9 / batch_stats.mean_ns;
                table.row(&[
                    label.to_string(),
                    dtype.name().to_string(),
                    k.to_string(),
                    fmt_ns(loop_stats.mean_ns),
                    fmt_ns(batch_stats.mean_ns),
                    format!("{speedup:.2}x"),
                    format!("{cols_per_s:.0}"),
                    resident.to_string(),
                ]);
                if k == 32 {
                    k32_ns[di] = batch_stats.mean_ns;
                    k32_entries.push((
                        format!("{}_{}", m.name(), dtype.name()),
                        obj(vec![
                            ("loop_ns", num(loop_stats.mean_ns)),
                            ("batch_ns", num(batch_stats.mean_ns)),
                            ("speedup", num(speedup)),
                            ("cols_per_s", num(cols_per_s)),
                            ("resident_bytes", num(resident as f64)),
                        ]),
                    ));
                }
            }
        }
        if m == Method::SHssRcm {
            hss_check = Some((
                c32.resident_weight_bytes(),
                c16.resident_weight_bytes(),
                k32_ns[0],
                k32_ns[1],
            ));
        }
    }
    table.print();

    // batched calibration step: one apply_batch + rank-k accumulate_grad
    // + Adam on the sHSS-RCM student, reported as rows (samples) per sec
    let batch = 32;
    let mut student = comp.compress(&w, Method::SHssRcm);
    let mut rng = Rng::new(5);
    let mut xb = Matrix::zeros(n, batch);
    rng.fill_gaussian(&mut xb.data);
    let targets: Vec<Vec<f32>> = (0..batch).map(|c| w.matvec(&xb.col(c))).collect();
    let tb = Matrix::from_cols(&targets);
    let mut gb = Matrix::zeros(n, batch);
    let mut grad = vec![0.0f32; num_params(&student)];
    let mut gws = GradWorkspace::for_matrix_batch(&student, batch);
    let mut ws = student.workspace_for(batch);
    let mut opt = OptimizerKind::Adam.build();
    let cal_stats = bench(
        || {
            grad.fill(0.0);
            student.apply_batch(&xb, &mut gb, &mut ws);
            for (g, &t) in gb.data.iter_mut().zip(&tb.data) {
                *g -= t;
            }
            accumulate_grad(&student, &xb, &gb, &mut grad, &mut gws);
            let inv = 1.0 / batch as f32;
            for g in grad.iter_mut() {
                *g *= inv;
            }
            opt.step(&mut student, &grad, 1e-3);
        },
        2,
        budget,
        10_000,
    );
    let rows_per_s = batch as f64 * 1e9 / cal_stats.mean_ns;
    println!(
        "\nbatched calibration step (shss-rcm, batch={batch}): {} per step, {rows_per_s:.0} rows/s",
        fmt_ns(cal_stats.mean_ns)
    );

    let (hss_f32, hss_f16, hss_ns32, hss_ns16) = hss_check.expect("shss-rcm case ran");

    // span-overhead check (CI-asserted): the observability guards wrapping
    // every spmm/hss_walk/lowrank call must cost ≤ 2% of a k = 32 shss-rcm
    // apply — measured WITH flight recording enabled, so the gate covers
    // the full cost of a guard: registry aggregate + per-batch span capture
    // + the ring flush amortized by end_batch. Measure one guard's
    // enter+drop cost in a tight loop inside a live batch context, count
    // how many guards one apply actually opens (global span-count delta),
    // and compare against the measured apply time.
    let reg = hisolo::obs::registry();
    let rec = hisolo::obs::recorder::recorder();
    let was_recording = rec.enabled();
    rec.set_enabled(true);
    let span_stats = bench(
        || {
            let flight = rec.begin_batch();
            for _ in 0..1000 {
                let _s = hisolo::obs::Span::enter(hisolo::obs::Stage::Spmm);
            }
            rec.end_batch(flight, &[]);
        },
        2,
        budget,
        10_000,
    );
    rec.set_enabled(was_recording);
    rec.reset();
    let span_ns = span_stats.mean_ns / 1000.0;
    let before = reg.total_count();
    student.apply_batch(&xb, &mut gb, &mut ws);
    let spans_per_apply = reg.total_count() - before;
    let overhead_pct = if reg.enabled() {
        spans_per_apply as f64 * span_ns / hss_ns32 * 100.0
    } else {
        0.0
    };
    println!(
        "span_overhead_check: {spans_per_apply} spans x {span_ns:.0}ns = {overhead_pct:.3}% \
         of k=32 shss-rcm apply ({}) {}",
        fmt_ns(hss_ns32),
        if overhead_pct <= 2.0 { "PASS" } else { "FAIL" }
    );

    // simd kernel race (CI-asserted): each dispatched compute kernel vs
    // its scalar arm at serving-shaped sizes (a d_model-class lane axis of
    // 1024). The arms are bit-identical by contract, so the race is purely
    // about throughput; PASS requires every kernel's scalar/simd time
    // ratio ≥ 0.95 (1.0 minus measurement noise). When the host has no
    // accelerated arm the race would time the same code twice, so it is
    // skipped as an identity and auto-passes.
    let best = simd::active_level();
    let mut simd_entries: Vec<(String, Json)> = vec![("level".to_string(), s(best.name()))];
    if best == simd::SimdLevel::Scalar {
        println!("\nsimd_check: level=scalar (no accelerated arm on this host) PASS");
    } else {
        let kdim = 1024usize; // multiple of simd::LANES: no tail in any arm
        let reps = 64usize;
        let mut srng = Rng::new(11);
        let mut av = vec![0.0f32; kdim];
        let mut bv = vec![0.0f32; 4 * kdim];
        srng.fill_gaussian(&mut av);
        srng.fill_gaussian(&mut bv);
        let hv: Vec<u16> = bv.iter().map(|&x| hisolo::util::fp16::f32_to_f16(x)).collect();
        let mut yv = vec![0.0f32; kdim];
        let mut wide = vec![0.0f32; 4 * kdim];
        let mut sink = 0.0f32;

        let race = |f: &mut dyn FnMut()| -> f64 {
            let prev = simd::force_level(simd::SimdLevel::Scalar);
            let scalar_ns = bench(|| f(), 2, budget, 10_000).mean_ns;
            simd::force_level(best);
            let simd_ns = bench(|| f(), 2, budget, 10_000).mean_ns;
            simd::force_level(prev);
            scalar_ns / simd_ns
        };

        let r_dot = race(&mut || {
            for _ in 0..reps {
                sink += simd::dot_k(std::hint::black_box(&av), &bv[..kdim]);
            }
        });
        let r_gemm = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..reps {
                let mut acc = [[0.0f32; 8]; 4];
                (kt.gemm_nt_microkernel)(
                    std::hint::black_box(&av),
                    [
                        &bv[..kdim],
                        &bv[kdim..2 * kdim],
                        &bv[2 * kdim..3 * kdim],
                        &bv[3 * kdim..4 * kdim],
                    ],
                    &mut acc,
                );
                sink += acc[0][0];
            }
        });
        let r_axpy = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..reps {
                (kt.axpy_k)(1.0001, std::hint::black_box(&av), &mut yv);
            }
        });
        let r_axpy4 = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..reps {
                (kt.axpy4_k)(&[0.1, 0.2, 0.3, 0.4], std::hint::black_box(&bv), kdim, &mut yv);
            }
        });
        let r_widen = race(&mut || {
            let kt = simd::kernels();
            for _ in 0..reps {
                (kt.widen_f16_lanes)(std::hint::black_box(&hv), &mut wide);
            }
        });
        std::hint::black_box(sink);

        let mut min_ratio = f64::INFINITY;
        for (name, r) in [
            ("dot", r_dot),
            ("gemm_mk", r_gemm),
            ("axpy", r_axpy),
            ("axpy4", r_axpy4),
            ("widen_f16", r_widen),
        ] {
            simd_entries.push((format!("{name}_ratio"), num(r)));
            min_ratio = min_ratio.min(r);
        }
        let verdict = if min_ratio >= 0.95 { "PASS" } else { "FAIL" };
        println!(
            "\nsimd_check: level={} dot={r_dot:.2}x gemm_mk={r_gemm:.2}x axpy={r_axpy:.2}x \
             axpy4={r_axpy4:.2}x widen_f16={r_widen:.2}x min_ratio={min_ratio:.2} {verdict}",
            best.name()
        );
    }

    // one-line JSON trajectory record (k = 32 per variant×dtype + resident
    // bytes + calibration + simd kernel ratios + the per-stage span
    // breakdown)
    let record = obj(vec![
        ("bench", s("batched_apply")),
        ("n", num(n as f64)),
        ("rank", num(rank as f64)),
        ("k32", Json::Obj(k32_entries.into_iter().collect())),
        ("hss_resident_bytes_f32", num(hss_f32 as f64)),
        ("hss_resident_bytes_f16", num(hss_f16 as f64)),
        ("calib_batch", num(batch as f64)),
        ("calib_rows_per_s", num(rows_per_s)),
        ("span_overhead_pct", num(overhead_pct)),
        ("simd", Json::Obj(simd_entries.into_iter().collect())),
        ("stages", reg.to_json()),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended k=32 trajectory line to {}", path.display());
    }

    // CI-asserted checks: resident memory must actually halve (values are
    // exactly 2 vs 4 bytes, so < 60% holds whenever any values exist) and
    // f16 throughput is reported relative to f32 (informational)
    let ratio = hss_f16 as f64 / hss_f32 as f64;
    let verdict = if ratio < 0.60 { "PASS" } else { "FAIL" };
    println!("f16_resident_check: shss-rcm f16/f32 = {ratio:.3} {verdict}");
    let rel = hss_ns16 / hss_ns32;
    println!(
        "f16_throughput_info: shss-rcm k=32 batch_ns f16/f32 = {rel:.3} ({})",
        if rel <= 1.10 { "within 10% or faster" } else { "slower than 10% budget" }
    );
}
