//! Incremental decoding evidence: paged-KV decode (one O(t) step per new
//! token) vs the pre-session serving shape (full-window rescore, O(t²)
//! across a conversation) at batch widths k ∈ {1, 8, 32}, plus the cache
//! hit rate under a prefix-sharing workload — every session opens with
//! the same system-prompt prefix, and odd sessions prefill after even
//! ones so the shared blocks are already published (`model::kvcache`
//! defers publishes to the end of a prefill batch).
//!
//! Every session prefills t0 tokens (default 256) and decodes to t1
//! (default 320), so all timed decode steps run at t ≥ 256 — the regime
//! the CI gate covers. The final `decode_check` line asserts, per batch
//! width: decode tokens/s beats rescore tokens/s, the prefill + decode
//! NLL sum is bit-identical to one full-window cache-writing prefill
//! under both the scalar and the detected-best SIMD level (both paths
//! read the same f16 page round-trip), and the pool's hit rate is > 0.
//! `--json <path>` appends a one-line `{"bench":"decode", ...}`
//! trajectory record.
//!
//!     cargo bench --bench decode [-- --tiny --t 320 --prompt 256
//!         --json traj.jsonl]

use hisolo::data::synthetic;
use hisolo::eval::perplexity::window_nll;
use hisolo::linalg::simd;
use hisolo::model::kvcache::{DEFAULT_BLOCK_SIZE, KvState};
use hisolo::model::transformer::DenseProjector;
use hisolo::model::{ModelConfig, Transformer};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::timer::Table;
use std::time::Instant;

struct DecodeRun {
    decode_tps: f64,
    hit_rate: f64,
    bitwise: bool,
}

/// Prefill `t0` tokens per session (two waves, so the second wave's
/// shared-prefix lookups hit blocks the first wave published), time the
/// decode loop t0 → t1 with all sessions batched per step, then check
/// the accumulated NLLs bit-match a full-window cache-writing prefill
/// of the grown windows (fresh session ids; single-token decodes add
/// row NLLs in the same left-to-right order the full prefill uses, so
/// f64 equality is exact, not approximate).
fn run_decode(
    model: &Transformer,
    proj: &DenseProjector,
    wins: &[Vec<u32>],
    t0: usize,
) -> DecodeRun {
    let t1 = wins[0].len();
    let k = wins.len();
    let mut kv = KvState::for_model(&model.cfg, 2048);
    let mut totals = vec![0.0f64; k];
    for wave in 0..2usize {
        let reqs: Vec<(u64, Vec<u32>)> = wins
            .iter()
            .enumerate()
            .filter(|(sid, _)| sid % 2 == wave)
            .map(|(sid, w)| (sid as u64, w[..t0].to_vec()))
            .collect();
        if reqs.is_empty() {
            continue;
        }
        for (req, res) in reqs.iter().zip(kv.prefill_batch(model, proj, &reqs)) {
            totals[req.0 as usize] = res.expect("prefill").0;
        }
    }

    // the timed O(t) path: one new token per session per step, every
    // step served by one batched decode over the cached pages
    let td = Instant::now();
    for i in t0..t1 {
        let reqs: Vec<(u64, Vec<u32>)> =
            (0..k).map(|sid| (sid as u64, vec![wins[sid][i]])).collect();
        for (req, res) in reqs.iter().zip(kv.decode(model, proj, &reqs)) {
            totals[req.0 as usize] += res.expect("decode").0;
        }
    }
    let decode_tps = ((t1 - t0) * k) as f64 / td.elapsed().as_secs_f64();

    // bitwise reference: re-prefill the grown windows under fresh ids
    // (their prompt blocks prefix-share the decode sessions' pages)
    let reqs: Vec<(u64, Vec<u32>)> = wins
        .iter()
        .enumerate()
        .map(|(sid, w)| (1000 + sid as u64, w.clone()))
        .collect();
    let mut bitwise = true;
    for (sid, res) in kv.prefill_batch(model, proj, &reqs).into_iter().enumerate() {
        let (nll, ntok) = res.expect("reference prefill");
        bitwise &= ntok == t1 - 1 && nll.to_bits() == totals[sid].to_bits();
    }
    DecodeRun {
        decode_tps,
        hit_rate: kv.stats().hit_rate(),
        bitwise,
    }
}

fn main() {
    let args = Args::parse(&["tiny"]);
    let t1 = args.get_usize("t", 320);
    let t0 = args.get_usize("prompt", 256);
    assert!(t0 >= 256, "--prompt must be >= 256 (the decode_check gate covers t >= 256)");
    assert!(t1 > t0, "--t must exceed --prompt (something to decode)");
    let cfg = if args.flag("tiny") {
        ModelConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            seq_len: t1,
        }
    } else {
        ModelConfig {
            vocab: 128,
            d_model: 128,
            n_heads: 4,
            n_layers: 2,
            d_ff: 256,
            seq_len: t1,
        }
    };
    let model = Transformer::random(cfg, 9);
    let proj = DenseProjector {
        layers: &model.layers,
    };

    // prefix-sharing workload: every session's window opens with the same
    // block-aligned system-prompt prefix, then diverges
    let ks = [1usize, 8, 32];
    let max_k = *ks.last().unwrap();
    let shared = t0 / 2 / DEFAULT_BLOCK_SIZE * DEFAULT_BLOCK_SIZE;
    let toks = synthetic::token_stream(shared + max_k * (t1 - shared), cfg.vocab);
    let wins: Vec<Vec<u32>> = (0..max_k)
        .map(|sid| {
            let mut w = toks[..shared].to_vec();
            let tail = shared + sid * (t1 - shared);
            w.extend_from_slice(&toks[tail..tail + (t1 - shared)]);
            w
        })
        .collect();

    println!(
        "== paged-KV decode vs full-window rescore: d={} t0={t0} t1={t1}, k sweep ==",
        cfg.d_model
    );
    println!(
        "   decode = one batched O(t) step per token; rescore = forward of the grown window\n"
    );
    let mut table = Table::new(&[
        "k",
        "decode tok/s",
        "rescore tok/s",
        "speedup",
        "kv hit rate",
        "bitwise",
    ]);
    let best = simd::active_level();
    let mut cases_json: Vec<(String, Json)> = Vec::new();
    let mut all_pass = true;
    let mut all_bitwise = true;
    let mut checks: Vec<String> = Vec::new();

    for &k in &ks {
        // bitwise gate under the forced scalar arm, then the detected-best
        // level (identity skip when the host has no accelerated arm) —
        // the timed decode numbers come from the best-level run
        let prev = simd::force_level(simd::SimdLevel::Scalar);
        let scalar_run = run_decode(&model, &proj, &wins[..k], t0);
        simd::force_level(prev);
        let best_run = if best == simd::SimdLevel::Scalar {
            None
        } else {
            Some(run_decode(&model, &proj, &wins[..k], t0))
        };
        let timed = best_run.as_ref().unwrap_or(&scalar_run);
        let bitwise = scalar_run.bitwise && best_run.as_ref().is_none_or(|r| r.bitwise);

        // the O(t²) serving shape this bench retires: every new token
        // re-scores its full grown window through the batched forward
        let decoded = (t1 - t0) * k;
        let tr = Instant::now();
        let mut sink = 0.0f64;
        for i in t0..t1 {
            let grown: Vec<&[u32]> = wins[..k].iter().map(|w| &w[..i]).collect();
            for (sid, lg) in model.forward_batch(&grown).iter().enumerate() {
                sink += window_nll(lg, &wins[sid][..=i]).0;
            }
        }
        assert!(sink.is_finite());
        let rescore_tps = decoded as f64 / tr.elapsed().as_secs_f64();

        let speedup = timed.decode_tps / rescore_tps;
        let pass = timed.decode_tps > rescore_tps && bitwise && timed.hit_rate > 0.0;
        all_pass &= pass;
        all_bitwise &= bitwise;
        checks.push(format!("k={k} speedup={speedup:.2}x"));
        table.row(&[
            k.to_string(),
            format!("{:.0}", timed.decode_tps),
            format!("{rescore_tps:.0}"),
            format!("{speedup:.2}x"),
            format!("{:.3}", timed.hit_rate),
            bitwise.to_string(),
        ]);
        cases_json.push((
            format!("k{k}"),
            obj(vec![
                ("decode_tps", num(timed.decode_tps)),
                ("rescore_tps", num(rescore_tps)),
                ("speedup", num(speedup)),
                ("kv_hit_rate", num(timed.hit_rate)),
                ("bitwise", Json::Bool(bitwise)),
            ]),
        ));
    }
    table.print();

    let verdict = if all_pass { "PASS" } else { "FAIL" };
    println!(
        "\ndecode_check: t0={t0} t1={t1} simd={} {} bitwise_all={all_bitwise} {verdict}",
        best.name(),
        checks.join(" ")
    );

    let record = obj(vec![
        ("bench", s("decode")),
        ("t0", num(t0 as f64)),
        ("t1", num(t1 as f64)),
        ("tiny", Json::Bool(args.flag("tiny"))),
        ("simd_level", s(best.name())),
        ("cases", Json::Obj(cases_json.into_iter().collect())),
        ("pass", Json::Bool(all_pass)),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended decode trajectory line to {}", path.display());
    }
    if !all_pass {
        std::process::exit(1);
    }
}
