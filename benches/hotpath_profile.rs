//! §Perf microbenches: the three native hot paths (matmul, HSS matvec,
//! transformer forward) with achieved-GFLOP/s so optimization progress is
//! measurable against the scalar-CPU roofline.
//!
//!     cargo bench --bench hotpath_profile

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::linalg::Matrix;
use hisolo::model::{ModelConfig, Transformer};
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let mut t = Table::new(&["hot path", "size", "time", "GFLOP/s"]);

    // --- dense matmul (drives fwd + compression) ---------------------------
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1);
        let b = Matrix::randn(n, n, 2);
        let bt = b.transpose();
        let mut c = Matrix::zeros(n, n);
        let s = bench(
            || a.matmul_bt_into(std::hint::black_box(&bt), &mut c),
            3,
            Duration::from_millis(400),
            10_000,
        );
        let flops = 2.0 * (n as f64).powi(3);
        t.row(&[
            "matmul_bt".into(),
            format!("{n}x{n}"),
            fmt_ns(s.mean_ns),
            format!("{:.2}", flops / s.mean_ns),
        ]);
    }

    // --- dense matvec -------------------------------------------------------
    for n in [256usize, 1024] {
        let a = Matrix::randn(n, n, 3);
        let x = vec![1.0f32; n];
        let mut y = vec![0.0f32; n];
        let s = bench(
            || a.matvec_into(std::hint::black_box(&x), &mut y),
            3,
            Duration::from_millis(300),
            100_000,
        );
        let flops = 2.0 * (n as f64) * (n as f64);
        t.row(&[
            "matvec".into(),
            format!("{n}x{n}"),
            fmt_ns(s.mean_ns),
            format!("{:.2}", flops / s.mean_ns),
        ]);
    }

    // --- HSS matvec ---------------------------------------------------------
    for n in [256usize, 1024] {
        let w = synthetic::trained_like(n, 4);
        let c = Compressor::new(CompressorConfig {
            rank: n / 8,
            sparsity: 0.1,
            depth: 3,
            ..Default::default()
        })
        .compress(&w, Method::SHssRcm);
        let x = vec![1.0f32; n];
        let mut y = vec![0.0f32; n];
        let mut ws = c.workspace();
        let s = bench(
            || c.matvec_with(std::hint::black_box(&x), &mut y, &mut ws),
            3,
            Duration::from_millis(300),
            100_000,
        );
        let flops = 2.0 * c.params() as f64; // one mul+add per stored param
        t.row(&[
            "hss matvec".into(),
            format!("{n}x{n}"),
            fmt_ns(s.mean_ns),
            format!("{:.2}", flops / s.mean_ns),
        ]);
    }

    // --- full transformer forward (the eval/serving unit) -------------------
    let cfg = ModelConfig::default();
    let model = Transformer::random(cfg, 5);
    let tokens: Vec<u32> = (0..cfg.seq_len as u32).map(|i| i % 256).collect();
    let s = bench(
        || {
            std::hint::black_box(model.forward(std::hint::black_box(&tokens)));
        },
        1,
        Duration::from_secs(3),
        50,
    );
    // fwd flops: per layer 4 d^2 t (qkvo) + 2 t^2 d (attn) + 4 d dff t (mlp),
    // plus 2 t d V logits
    let (d, tt, ff, v) = (
        cfg.d_model as f64,
        cfg.seq_len as f64,
        cfg.d_ff as f64,
        cfg.vocab as f64,
    );
    let flops = cfg.n_layers as f64 * (2.0 * 4.0 * d * d * tt + 2.0 * 2.0 * tt * tt * d + 2.0 * 2.0 * d * ff * tt)
        + 2.0 * tt * d * v;
    t.row(&[
        "transformer fwd".into(),
        format!("t={} d={}", cfg.seq_len, cfg.d_model),
        fmt_ns(s.mean_ns),
        format!("{:.2}", flops / s.mean_ns),
    ]);

    t.print();
}
