//! One calibration step per `CompressedMatrix` variant at n=512 —
//! batched forward + rank-k backward over a mini-batch plus the Adam
//! update (one `apply_batch` + one `accumulate_grad` call per step),
//! reported as steps/sec so the training hot loop enters the perf
//! trajectory next to the matvec/compress benches.
//!
//! Run: `cargo bench --bench train_step [-- --n 512 --batch 16]`

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::linalg::Matrix;
use hisolo::train::{accumulate_grad, num_params, GradWorkspace, Optimizer, OptimizerKind};
use hisolo::util::cli::Args;
use hisolo::util::rng::Rng;
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let n = args.get_usize("n", 512);
    let batch = args.get_usize("batch", 16);
    let rank = args.get_usize("rank", n / 16);
    let teacher = synthetic::trained_like(n, 42);

    let mut rng = Rng::new(7);
    // sample block X [n, batch] and its dense-teacher targets T = W X
    let mut xb = Matrix::zeros(n, batch);
    rng.fill_gaussian(&mut xb.data);
    let targets: Vec<Vec<f32>> = (0..batch).map(|c| teacher.matvec(&xb.col(c))).collect();
    let tb = Matrix::from_cols(&targets);

    println!("train_step: n={n} batch={batch} rank={rank} (adam, one optimizer step)");
    let mut table = Table::new(&["variant", "params", "step time", "steps/s", "samples/s"]);

    let cases: [(&str, Method); 3] = [
        ("lowrank (svd)", Method::Svd),
        ("lowrank+csr (ssvd)", Method::SSvd),
        ("hss (shss-rcm)", Method::SHssRcm),
    ];
    for (label, method) in cases {
        let cfg = CompressorConfig {
            rank,
            sparsity: 0.1,
            depth: 3,
            ..Default::default()
        };
        let mut student = Compressor::new(cfg).compress(&teacher, method);
        let np = num_params(&student);
        let mut grad = vec![0.0f32; np];
        let mut gws = GradWorkspace::for_matrix_batch(&student, batch);
        let mut ws = student.workspace_for(batch);
        let mut gb = Matrix::zeros(n, batch);
        let mut opt = OptimizerKind::Adam.build();

        let stats = bench(
            || {
                grad.fill(0.0);
                student.apply_batch(&xb, &mut gb, &mut ws);
                for (g, &t) in gb.data.iter_mut().zip(&tb.data) {
                    *g -= t; // G = Ŷ − T
                }
                accumulate_grad(&student, &xb, &gb, &mut grad, &mut gws);
                let inv = 1.0 / batch as f32;
                for g in grad.iter_mut() {
                    *g *= inv;
                }
                opt.step(&mut student, &grad, 1e-3);
            },
            2,
            Duration::from_secs(2),
            500,
        );
        let steps_per_s = 1e9 / stats.mean_ns;
        table.row(&[
            label.to_string(),
            np.to_string(),
            fmt_ns(stats.mean_ns),
            format!("{steps_per_s:.1}"),
            format!("{:.0}", steps_per_s * batch as f64),
        ]);
    }
    table.print();
}
