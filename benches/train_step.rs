//! One calibration step per `CompressedMatrix` variant at n=512 —
//! forward + backward over a mini-batch plus the Adam update, reported as
//! steps/sec so the training hot loop enters the perf trajectory next to
//! the matvec/compress benches.
//!
//! Run: `cargo bench --bench train_step [-- --n 512 --batch 16]`

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::train::{accumulate_grad, num_params, GradWorkspace, Optimizer, OptimizerKind};
use hisolo::util::cli::Args;
use hisolo::util::rng::Rng;
use hisolo::util::timer::{bench, fmt_ns, Table};
use std::time::Duration;

fn main() {
    let args = Args::parse(&[]);
    let n = args.get_usize("n", 512);
    let batch = args.get_usize("batch", 16);
    let rank = args.get_usize("rank", n / 16);
    let teacher = synthetic::trained_like(n, 42);

    let mut rng = Rng::new(7);
    let xs: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
        .collect();
    let targets: Vec<Vec<f32>> = xs.iter().map(|x| teacher.matvec(x)).collect();

    println!("train_step: n={n} batch={batch} rank={rank} (adam, one optimizer step)");
    let mut table = Table::new(&["variant", "params", "step time", "steps/s", "samples/s"]);

    let cases: [(&str, Method); 3] = [
        ("lowrank (svd)", Method::Svd),
        ("lowrank+csr (ssvd)", Method::SSvd),
        ("hss (shss-rcm)", Method::SHssRcm),
    ];
    for (label, method) in cases {
        let cfg = CompressorConfig {
            rank,
            sparsity: 0.1,
            depth: 3,
            ..Default::default()
        };
        let mut student = Compressor::new(cfg).compress(&teacher, method);
        let np = num_params(&student);
        let mut grad = vec![0.0f32; np];
        let mut gws = GradWorkspace::for_matrix(&student);
        let mut ws = student.workspace();
        let mut y = vec![0.0f32; n];
        let mut opt = OptimizerKind::Adam.build();

        let stats = bench(
            || {
                grad.fill(0.0);
                for (x, t) in xs.iter().zip(&targets) {
                    student.matvec_with(x, &mut y, &mut ws);
                    for (yy, &tt) in y.iter_mut().zip(t) {
                        *yy -= tt;
                    }
                    accumulate_grad(&student, x, &y, &mut grad, &mut gws);
                }
                let inv = 1.0 / batch as f32;
                for g in grad.iter_mut() {
                    *g *= inv;
                }
                opt.step(&mut student, &grad, 1e-3);
            },
            2,
            Duration::from_secs(2),
            500,
        );
        let steps_per_s = 1e9 / stats.mean_ns;
        table.row(&[
            label.to_string(),
            np.to_string(),
            fmt_ns(stats.mean_ns),
            format!("{steps_per_s:.1}"),
            format!("{:.0}", steps_per_s * batch as f64),
        ]);
    }
    table.print();
}
