//! §5.4 reproduction: the role of RCM reordering.
//!
//! Measures, per trained projection: pattern bandwidth and diagonal-band
//! magnitude mass before/after RCM, and the HSS reconstruction error with
//! and without reordering, at each sparsity level. Also the isolated
//! shuffled-banded case where RCM provably wins.
//!
//!     cargo bench --bench rcm_role

mod common;

use hisolo::data::synthetic;
use hisolo::hss::{build, HssOptions};
use hisolo::linalg::norms::rel_fro_error;
use hisolo::sparse::bandwidth::{bandwidth, mass_within_band};
use hisolo::sparse::graph::{magnitude_quantile, Graph};
use hisolo::sparse::{rcm, top_p_extract};
use hisolo::util::timer::Table;

fn main() {
    let env = common::load_env(1);

    println!("== §5.4: RCM effect on trained projections ==\n");
    let mut t = Table::new(&[
        "projection", "sp", "bw before", "bw after", "mass@16 before",
        "mass@16 after", "err sHSS", "err sHSS-RCM",
    ]);
    for (name, w) in env.model.qkv_projections().into_iter().take(3) {
        let a = w.transpose();
        for sp in [0.10, 0.30] {
            let (_s, resid) = top_p_extract(&a, sp);
            let g = Graph::from_pattern(&resid, 0.90);
            let p = rcm(&g);
            let reordered = resid.permute_sym(p.indices());
            let thresh = magnitude_quantile(&resid, 0.90);

            let mk = |use_rcm| HssOptions {
                rank: 32,
                sparsity: sp,
                depth: 3,
                use_rcm,
                ..Default::default()
            };
            let err_plain = rel_fro_error(&build(&a, &mk(false)).reconstruct(), &a);
            let err_rcm = rel_fro_error(&build(&a, &mk(true)).reconstruct(), &a);

            t.row(&[
                name.clone(),
                format!("{:.0}%", sp * 100.0),
                bandwidth(&resid, thresh).to_string(),
                bandwidth(&reordered, thresh).to_string(),
                format!("{:.3}", mass_within_band(&resid, 16)),
                format!("{:.3}", mass_within_band(&reordered, 16)),
                format!("{err_plain:.4}"),
                format!("{err_rcm:.4}"),
            ]);
        }
    }
    t.print();

    println!("\n== isolated case: banded structure hidden by a permutation ==\n");
    let mut t2 = Table::new(&["n", "err sHSS", "err sHSS-RCM", "rcm wins"]);
    for n in [128usize, 256] {
        let a = synthetic::shuffled_banded(n, 6, 42);
        let mk = |use_rcm| HssOptions {
            rank: 8,
            sparsity: 0.0,
            depth: 2,
            use_rcm,
            pattern_quantile: 0.85,
            rsvd: false,
            ..Default::default()
        };
        let e0 = rel_fro_error(&build(&a, &mk(false)).reconstruct(), &a);
        let e1 = rel_fro_error(&build(&a, &mk(true)).reconstruct(), &a);
        t2.row(&[
            n.to_string(),
            format!("{e0:.4}"),
            format!("{e1:.4}"),
            (e1 < e0).to_string(),
        ]);
    }
    t2.print();
    println!(
        "\npaper shape: on trained LLM projections RCM is a slight, mostly\n\
         consistent gain (\"slight gain with RCM\"); on latent banded\n\
         structure it is decisive."
    );
}
