//! Figure 2 reproduction: sparsity ablation for the hierarchical methods.
//!
//! Paper setting: rank 512, depth 4, sp ∈ {10, 20, 30} — PPL of sHSS vs
//! sHSS-RCM. Scaled here to rank d/8 = 32 at d = 256, depth 4 (leaves 16).
//!
//!     cargo bench --bench fig2_ablation

mod common;

use hisolo::compress::{CompressorConfig, Method};
use hisolo::eval::sweep::eval_point;
use hisolo::util::timer::Table;

fn main() {
    let env = common::load_env(12);
    let threads = common::threads();
    println!(
        "== Figure 2: PPL ablation, rank 32 (paper: 512@4096), depth 4, sp10/20/30 ==\n\
         ({} windows x {} tokens, {} threads)\n",
        env.windows.len(),
        env.model.cfg.seq_len,
        threads
    );

    let dense = eval_point(
        &env.model,
        Method::Dense,
        CompressorConfig::default(),
        &env.windows,
        threads,
    );
    println!("dense baseline ppl: {:.4}\n", dense.ppl);

    let mut t = Table::new(&["sp", "method", "ppl", "d_ppl vs dense", "qkv ratio"]);
    for sp in [0.10, 0.20, 0.30] {
        for method in [Method::SHss, Method::SHssRcm] {
            let cfg = CompressorConfig {
                rank: 32,
                sparsity: sp,
                depth: 4,
                min_leaf: 8,
                ..Default::default()
            };
            let p = eval_point(&env.model, method, cfg, &env.windows, threads);
            t.row(&[
                format!("sp{:.0}", sp * 100.0),
                p.method.paper_label().to_string(),
                format!("{:.4}", p.ppl),
                format!("{:+.4}", p.ppl - dense.ppl),
                format!("{:.3}", p.qkv_ratio()),
            ]);
            eprintln!("done: sp{:.0} {}", sp * 100.0, method.paper_label());
        }
    }
    t.print();
    println!(
        "\npaper shape: higher sp => lower PPL at fixed rank; RCM helps most\n\
         at sp10 and is roughly neutral at sp20/sp30 (Fig 2, §5.4)."
    );
}
