//! Serving bench: coordinator throughput/latency, dense vs sHSS variants,
//! and the dynamic-batching ablation (max_batch 1 vs 8).
//!
//! Exercises the full L3 path: batcher -> worker -> PJRT executable (AOT
//! L2 graph with L1 Pallas kernels) when artifacts exist, else the native
//! forward pass.
//!
//!     cargo bench --bench coordinator_throughput

mod common;

use hisolo::coordinator::worker::{NativeCompressedScorer, NativeDenseScorer};
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::compress::{CompressorConfig, Method};
use hisolo::model::{CompressedModel, WeightFile};
use hisolo::runtime::{ArtifactDir, Runtime};
use hisolo::util::timer::Table;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let env = common::load_env(48);
    let mut t = Table::new(&[
        "backend", "variant", "max_batch", "req/s", "p50 ms", "p95 ms", "mean batch",
    ]);

    for max_batch in [1usize, 8] {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(5),
                capacity: 4096,
                ..BatcherConfig::default()
            },
        };

        // --- native backend ------------------------------------------------
        let mut coord = Coordinator::new(cfg.clone());
        coord.add_worker(
            Variant::Dense,
            NativeDenseScorer {
                model: env.model.clone(),
                max_batch,
            },
        );
        let cm = Arc::new(CompressedModel::compress(
            env.model.clone(),
            Method::SHssRcm,
            CompressorConfig {
                rank: 32,
                sparsity: 0.3,
                depth: 3,
                ..Default::default()
            },
        ));
        coord.add_worker(
            Variant::Hss,
            NativeCompressedScorer {
                model: cm,
                max_batch,
            },
        );
        for variant in [Variant::Dense, Variant::Hss] {
            run_case(&coord, variant, &env.windows, "native", max_batch, &mut t);
        }
        coord.shutdown();

        // --- pjrt backend (AOT executables) ---------------------------------
        if let Some(dir) = env.dir.clone() {
            let mut coord = Coordinator::new(cfg);
            for (variant, exe) in [
                (Variant::Dense, "model_dense_b8"),
                (Variant::Hss, "model_hss_b8"),
            ] {
                let dir = dir.clone();
                coord.add_worker_factory(variant, move || {
                    let a = ArtifactDir::load(&dir)?;
                    let weights = WeightFile::load(&dir.join("model.hwt"))?;
                    let rt = Runtime::cpu()?;
                    if exe.contains("hss") {
                        let ops = WeightFile::load(&dir.join("hss_operands.hwt"))?;
                        rt.load_model(&a, exe, &[&weights, &ops])
                    } else {
                        rt.load_model(&a, exe, &[&weights])
                    }
                });
            }
            for variant in [Variant::Dense, Variant::Hss] {
                run_case(&coord, variant, &env.windows, "pjrt", max_batch, &mut t);
            }
            coord.shutdown();
        }
        eprintln!("done max_batch={max_batch}");
    }
    t.print();
    println!(
        "\npaper claim: compressed models retain full inference speed (batched\n\
         kernels); batching ablation shows the coordinator's max_batch lever."
    );
}

fn run_case(
    coord: &Coordinator,
    variant: Variant,
    windows: &[Vec<u32>],
    backend: &str,
    max_batch: usize,
    t: &mut Table,
) {
    // warmup (compile/camp the executable)
    let _ = coord.submit_all(variant, &windows[..2.min(windows.len())]);
    let t0 = Instant::now();
    let resps = coord.submit_all(variant, windows).expect("serve");
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = resps.iter().find_map(|r| r.error.clone()) {
        panic!("{backend}/{}: {e}", variant.name());
    }
    let mut lat: Vec<u64> = resps.iter().map(|r| r.latency_us).collect();
    lat.sort_unstable();
    let mean_batch =
        resps.iter().map(|r| r.batch_size).sum::<usize>() as f64 / resps.len() as f64;
    t.row(&[
        backend.to_string(),
        variant.name().to_string(),
        max_batch.to_string(),
        format!("{:.1}", resps.len() as f64 / wall),
        format!("{:.1}", lat[lat.len() / 2] as f64 / 1e3),
        format!("{:.1}", lat[lat.len() * 95 / 100] as f64 / 1e3),
        format!("{mean_batch:.2}"),
    ]);
}
