//! Serving bench: coordinator throughput/latency, dense vs sHSS variants,
//! and the dynamic-batching ablation (max_batch 1 vs 8).
//!
//! Exercises the full L3 path: batcher -> worker -> PJRT executable (AOT
//! L2 graph with L1 Pallas kernels) when artifacts exist, else the native
//! forward pass. Every run ends with a one-line JSON trajectory record
//! (per-case req/s and latency percentiles); `--json <path>` appends it
//! to a file, `--tiny` shrinks the model for CI smoke runs, and
//! `--requests N` sets the request count (default 48).
//!
//! `--sessions` adds a multi-turn session case: 16 sessions prefill a
//! shared prompt through a paged-KV dense worker, then decode one token
//! per turn (`submit_prefill`/`submit_decode`), raced against the
//! equivalent O(t²) full-window rescore traffic through the same
//! coordinator — decode throughput lands alongside the rescore cases in
//! the trajectory record.
//!
//!     cargo bench --bench coordinator_throughput [-- --tiny --requests 24
//!         --sessions --json traj.jsonl]

mod common;

use hisolo::coordinator::worker::{NativeCompressedScorer, NativeDenseScorer};
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::compress::{CompressorConfig, Method};
use hisolo::data::dataset::windows;
use hisolo::data::synthetic;
use hisolo::model::kvcache::DEFAULT_BLOCK_SIZE;
use hisolo::model::{CompressedModel, ModelConfig, Transformer, WeightFile};
use hisolo::runtime::{ArtifactDir, Runtime};
use hisolo::util::cli::Args;
use hisolo::util::json::{num, obj, s, Json};
use hisolo::util::timer::Table;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::parse(&["tiny", "sessions"]);
    let n_requests = args.get_usize("requests", 48);
    let env = if args.flag("tiny") {
        // same shrunken config `hisolo serve --synthetic --tiny` uses, so
        // the CI smoke trajectory tracks the code path the smoke serves
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            seq_len: 32,
        };
        let toks = synthetic::token_stream(20_000, cfg.vocab);
        common::BenchEnv {
            model: Arc::new(Transformer::random(cfg, 7)),
            windows: windows(&toks, cfg.seq_len, n_requests),
            from_artifacts: false,
            dir: None,
        }
    } else {
        common::load_env(n_requests)
    };
    let mut t = Table::new(&[
        "backend", "variant", "max_batch", "req/s", "p50 ms", "p95 ms", "mean batch",
    ]);
    let mut cases_json: Vec<(String, Json)> = Vec::new();

    for max_batch in [1usize, 8] {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(5),
                capacity: 4096,
                ..BatcherConfig::default()
            },
        };

        // --- native backend ------------------------------------------------
        let mut coord = Coordinator::new(cfg.clone());
        coord.add_worker(
            Variant::Dense,
            NativeDenseScorer {
                model: env.model.clone(),
                max_batch,
                kv: None,
            },
        );
        let cm = Arc::new(CompressedModel::compress(
            env.model.clone(),
            Method::SHssRcm,
            CompressorConfig {
                rank: 32,
                sparsity: 0.3,
                depth: 3,
                ..Default::default()
            },
        ));
        coord.add_worker(
            Variant::Hss,
            NativeCompressedScorer {
                model: cm,
                max_batch,
                kv: None,
            },
        );
        for variant in [Variant::Dense, Variant::Hss] {
            run_case(&coord, variant, &env.windows, "native", max_batch, &mut t, &mut cases_json);
        }
        coord.shutdown();

        // --- pjrt backend (AOT executables) ---------------------------------
        if let Some(dir) = env.dir.clone() {
            let mut coord = Coordinator::new(cfg);
            for (variant, exe) in [
                (Variant::Dense, "model_dense_b8"),
                (Variant::Hss, "model_hss_b8"),
            ] {
                let dir = dir.clone();
                coord.add_worker_factory(variant, move || {
                    let a = ArtifactDir::load(&dir)?;
                    let weights = WeightFile::load(&dir.join("model.hwt"))?;
                    let rt = Runtime::cpu()?;
                    if exe.contains("hss") {
                        let ops = WeightFile::load(&dir.join("hss_operands.hwt"))?;
                        rt.load_model(&a, exe, &[&weights, &ops])
                    } else {
                        rt.load_model(&a, exe, &[&weights])
                    }
                });
            }
            for variant in [Variant::Dense, Variant::Hss] {
                run_case(&coord, variant, &env.windows, "pjrt", max_batch, &mut t, &mut cases_json);
            }
            coord.shutdown();
        }
        eprintln!("done max_batch={max_batch}");
    }
    if args.flag("sessions") {
        run_sessions_case(&env, &mut t, &mut cases_json);
    }
    t.print();
    println!(
        "\npaper claim: compressed models retain full inference speed (batched\n\
         kernels); batching ablation shows the coordinator's max_batch lever."
    );

    // one-line JSON trajectory record (per backend×variant×max_batch case)
    let record = obj(vec![
        ("bench", s("coordinator_throughput")),
        ("requests", num(env.windows.len() as f64)),
        ("tiny", Json::Bool(args.flag("tiny"))),
        ("from_artifacts", Json::Bool(env.from_artifacts)),
        ("cases", Json::Obj(cases_json.into_iter().collect())),
    ]);
    println!("\nJSON: {record}");
    if let Some(path) = args.get_path("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .expect("open json trajectory file");
        writeln!(f, "{record}").expect("append trajectory line");
        println!("appended coordinator trajectory line to {}", path.display());
    }
}

/// Multi-turn session traffic through the coordinator: paired sessions
/// share a prompt (two prefill waves, so the second wave's lookups hit
/// pages the first wave published), then every turn appends one token
/// per session via `submit_decode` — timed against the equivalent
/// pre-session traffic, where every turn rescores its full grown window.
fn run_sessions_case(env: &common::BenchEnv, t: &mut Table, cases_json: &mut Vec<(String, Json)>) {
    // windows carry seq_len + 1 tokens (inputs + targets); sessions cache
    // at most seq_len positions, so decode turns stop there
    let seq_len = env.model.cfg.seq_len;
    let n_sessions = 16usize;
    let prompt = (seq_len / 2).max(1);
    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            capacity: 4096,
            ..BatcherConfig::default()
        },
    });
    let pages = n_sessions * seq_len.div_ceil(DEFAULT_BLOCK_SIZE) + 8;
    coord.add_worker(
        Variant::Dense,
        NativeDenseScorer::new(env.model.clone(), 8).with_kv_pages(pages),
    );
    let window_of = |sid: usize| &env.windows[(sid / 2) % env.windows.len()];

    for wave in 0..2usize {
        let pending: Vec<_> = (0..n_sessions)
            .filter(|sid| sid % 2 == wave)
            .map(|sid| {
                let w = window_of(sid)[..prompt].to_vec();
                coord
                    .submit_prefill(Variant::Dense, sid as u64, w)
                    .expect("submit prefill")
            })
            .collect();
        for rx in pending {
            let r = rx.recv().expect("prefill reply");
            assert!(r.error.is_none(), "prefill: {:?}", r.error);
        }
    }

    // decode turns: one token per session per step; the batcher coalesces
    // the single-token requests into decode-class buckets
    let t0 = Instant::now();
    let mut lat: Vec<u64> = Vec::new();
    let mut batch_sum = 0usize;
    for i in prompt..seq_len {
        let pending: Vec<_> = (0..n_sessions)
            .map(|sid| {
                coord
                    .submit_decode(Variant::Dense, sid as u64, vec![window_of(sid)[i]])
                    .expect("submit decode")
            })
            .collect();
        for rx in pending {
            let r = rx.recv().expect("decode reply");
            assert!(r.error.is_none(), "decode: {:?}", r.error);
            lat.push(r.latency_us);
            batch_sum += r.batch_size;
        }
    }
    let decoded = (seq_len - prompt) * n_sessions;
    let decode_tps = decoded as f64 / t0.elapsed().as_secs_f64();

    // the pre-session shape of the same traffic: every turn re-scores the
    // full grown window (O(t²) tokens across the conversation)
    let t0 = Instant::now();
    for i in prompt..seq_len {
        let grown: Vec<Vec<u32>> =
            (0..n_sessions).map(|sid| window_of(sid)[..=i].to_vec()).collect();
        let resps = coord.submit_all(Variant::Dense, &grown).expect("rescore");
        assert!(resps.iter().all(|r| r.error.is_none()), "rescore errored");
    }
    let rescore_tps = decoded as f64 / t0.elapsed().as_secs_f64();
    let hit_rate = coord.metrics.kv_hit_rate();
    coord.shutdown();

    lat.sort_unstable();
    let p50_us = lat[lat.len() / 2];
    let p95_us = lat[(lat.len() * 95 / 100).min(lat.len() - 1)];
    t.row(&[
        "native-kv".to_string(),
        "dense".to_string(),
        "8".to_string(),
        format!("{decode_tps:.1}"),
        format!("{:.1}", p50_us as f64 / 1e3),
        format!("{:.1}", p95_us as f64 / 1e3),
        format!("{:.2}", batch_sum as f64 / lat.len() as f64),
    ]);
    println!(
        "sessions: n={n_sessions} prompt={prompt} decode_tok_per_s={decode_tps:.0} \
         rescore_tok_per_s={rescore_tps:.0} speedup={:.2}x kv_hit_rate={hit_rate:.3}",
        decode_tps / rescore_tps
    );
    cases_json.push((
        "sessions_decode".to_string(),
        obj(vec![
            ("sessions", num(n_sessions as f64)),
            ("prompt", num(prompt as f64)),
            ("decode_tok_per_s", num(decode_tps)),
            ("rescore_tok_per_s", num(rescore_tps)),
            ("speedup", num(decode_tps / rescore_tps)),
            ("kv_hit_rate", num(hit_rate)),
            ("p50_us", num(p50_us as f64)),
            ("p95_us", num(p95_us as f64)),
        ]),
    ));
}

fn run_case(
    coord: &Coordinator,
    variant: Variant,
    windows: &[Vec<u32>],
    backend: &str,
    max_batch: usize,
    t: &mut Table,
    cases_json: &mut Vec<(String, Json)>,
) {
    // warmup (compile/camp the executable)
    let _ = coord.submit_all(variant, &windows[..2.min(windows.len())]);
    let t0 = Instant::now();
    let resps = coord.submit_all(variant, windows).expect("serve");
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = resps.iter().find_map(|r| r.error.clone()) {
        panic!("{backend}/{}: {e}", variant.name());
    }
    let mut lat: Vec<u64> = resps.iter().map(|r| r.latency_us).collect();
    lat.sort_unstable();
    let mean_batch =
        resps.iter().map(|r| r.batch_size).sum::<usize>() as f64 / resps.len() as f64;
    let req_per_s = resps.len() as f64 / wall;
    let p50_us = lat[lat.len() / 2];
    let p95_us = lat[(lat.len() * 95 / 100).min(lat.len() - 1)];
    t.row(&[
        backend.to_string(),
        variant.name().to_string(),
        max_batch.to_string(),
        format!("{req_per_s:.1}"),
        format!("{:.1}", p50_us as f64 / 1e3),
        format!("{:.1}", p95_us as f64 / 1e3),
        format!("{mean_batch:.2}"),
    ]);
    cases_json.push((
        format!("{backend}_{}_b{max_batch}", variant.name()),
        obj(vec![
            ("req_per_s", num(req_per_s)),
            ("p50_us", num(p50_us as f64)),
            ("p95_us", num(p95_us as f64)),
            ("mean_batch", num(mean_batch)),
        ]),
    ));
}
