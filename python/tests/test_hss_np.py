"""Build-time compression pipeline invariants (hss_np)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hss_np


def trained_like(n, seed=0, spikes=None):
    """Matrix with the structure the method exploits: smooth low-rank-ish
    bulk + a few large-magnitude spikes."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)) * 0.02
    a += (rng.standard_normal((n, 8)) @ rng.standard_normal((8, n))) * 0.1
    ns = spikes if spikes is not None else 3 * n
    idx = rng.integers(0, n, (ns, 2))
    a[idx[:, 0], idx[:, 1]] += rng.standard_normal(ns) * 2
    return a


class TestTopP:
    def test_capacity_exact(self):
        a = trained_like(32)
        rows, cols, vals = hss_np.top_p_coo(a, 0.1)
        assert len(vals) == int(0.1 * 32 * 32)

    def test_picks_largest(self):
        a = np.zeros((8, 8))
        a[3, 5] = 10.0
        a[1, 2] = -20.0
        rows, cols, vals = hss_np.top_p_coo(a, 2 / 64)
        got = set(zip(rows.tolist(), cols.tolist()))
        assert got == {(3, 5), (1, 2)}

    def test_rows_sorted(self):
        a = trained_like(64, seed=3)
        rows, _, _ = hss_np.top_p_coo(a, 0.2)
        assert np.all(np.diff(rows) >= 0)

    def test_zero_budget(self):
        rows, cols, vals = hss_np.top_p_coo(trained_like(16), 0.0)
        assert len(vals) == 0

    def test_residual_plus_sparse_is_exact(self):
        a = trained_like(32, seed=5)
        rows, cols, vals = hss_np.top_p_coo(a, 0.15)
        s = hss_np.coo_to_dense(rows, cols, vals, a.shape)
        resid = a - s
        np.testing.assert_allclose(s + resid, a, rtol=1e-6, atol=1e-7)


class TestRcm:
    def test_is_permutation(self):
        a = trained_like(64, seed=1)
        p = hss_np.rcm_permutation(a, 0.9)
        assert sorted(p.tolist()) == list(range(64))

    def test_reduces_bandwidth_on_banded_shuffled(self):
        n = 64
        rng = np.random.default_rng(2)
        band = np.zeros((n, n))
        for i in range(n):
            for j in range(max(0, i - 3), min(n, i + 4)):
                band[i, j] = rng.standard_normal() + 1.0
        perm = rng.permutation(n)
        shuffled = band[np.ix_(perm, perm)]

        def bandwidth(m):
            r, c = np.nonzero(np.abs(m) > 1e-12)
            return int(np.max(np.abs(r - c))) if len(r) else 0

        p = hss_np.rcm_permutation(shuffled, 0.0)
        reordered = shuffled[np.ix_(p, p)]
        assert bandwidth(reordered) < bandwidth(shuffled)


class TestBuild:
    @pytest.mark.parametrize("use_rcm", [False, True])
    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_matvec_equals_reconstruct(self, use_rcm, depth):
        a = trained_like(64, seed=depth)
        cfg = hss_np.HssConfig(rank=8, sparsity=0.1, depth=depth,
                               use_rcm=use_rcm, min_leaf=4)
        node = hss_np.build(a, cfg)
        rec = hss_np.reconstruct(node)
        x = np.random.default_rng(0).standard_normal((64, 5))
        np.testing.assert_allclose(hss_np.apply(node, x), rec @ x,
                                   rtol=1e-5, atol=1e-6)

    def test_full_rank_depth1_exact(self):
        a = trained_like(32, seed=9)
        cfg = hss_np.HssConfig(rank=16, sparsity=0.2, depth=1, rsvd=False)
        node = hss_np.build(a, cfg)
        err = np.linalg.norm(hss_np.reconstruct(node) - a) / np.linalg.norm(a)
        assert err < 1e-6

    def test_error_decreases_with_rank(self):
        a = trained_like(64, seed=4)
        errs = []
        for rank in (2, 8, 32):
            cfg = hss_np.HssConfig(rank=rank, sparsity=0.1, depth=2, rsvd=False)
            rec = hss_np.reconstruct(hss_np.build(a, cfg))
            errs.append(np.linalg.norm(rec - a) / np.linalg.norm(a))
        assert errs[0] > errs[1] > errs[2]

    def test_error_decreases_with_sparsity(self):
        a = trained_like(64, seed=6)
        errs = []
        for sp in (0.0, 0.1, 0.3):
            cfg = hss_np.HssConfig(rank=4, sparsity=sp, depth=2, rsvd=False)
            rec = hss_np.reconstruct(hss_np.build(a, cfg))
            errs.append(np.linalg.norm(rec - a) / np.linalg.norm(a))
        assert errs[0] > errs[1] > errs[2]

    def test_rank_halves_per_level(self):
        a = trained_like(128, seed=7)
        cfg = hss_np.HssConfig(rank=16, sparsity=0.05, depth=3, min_leaf=4,
                               tol=0.0)
        node = hss_np.build(a, cfg)
        assert node.u0.shape[1] == 16
        assert node.child0.u0.shape[1] == 8
        assert node.child0.child0.u0.shape[1] == 4

    def test_storage_less_than_dense(self):
        a = trained_like(128, seed=8)
        cfg = hss_np.HssConfig(rank=8, sparsity=0.1, depth=3)
        node = hss_np.build(a, cfg)
        assert hss_np.storage_params(node) < a.size

    def test_flatten_spec_roundtrip_consistency(self):
        a = trained_like(64, seed=10)
        cfg = hss_np.HssConfig(rank=8, sparsity=0.1, depth=2)
        node = hss_np.build(a, cfg)
        names = [n for n, _ in hss_np.flatten(node, "w")]
        assert len(names) == len(set(names))
        sp = hss_np.spec(node)
        assert sp["n"] == 64 and not sp["leaf"]
        assert sp["c0"]["n"] == 32

    @settings(max_examples=8, deadline=None)
    @given(n=st.sampled_from([32, 64]), rank=st.integers(2, 12),
           sp=st.floats(0.0, 0.3), rcm=st.booleans())
    def test_matvec_reconstruct_sweep(self, n, rank, sp, rcm):
        a = trained_like(n, seed=rank)
        cfg = hss_np.HssConfig(rank=rank, sparsity=sp, depth=2,
                               use_rcm=rcm, min_leaf=4)
        node = hss_np.build(a, cfg)
        rec = hss_np.reconstruct(node)
        x = np.random.default_rng(1).standard_normal((n, 3))
        np.testing.assert_allclose(hss_np.apply(node, x), rec @ x,
                                   rtol=1e-5, atol=1e-6)
