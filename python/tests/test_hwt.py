"""HWT container format round-trip (the python half of the cross-language
contract; rust/src/model/weights.rs has the mirror tests + a shared golden
fixture under tests/fixtures)."""

import os
import tempfile

import numpy as np
import pytest

from compile import hwt


def roundtrip(tensors):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.hwt")
        hwt.save(path, tensors)
        return hwt.load_ordered(path)


class TestHwt:
    def test_f32_roundtrip(self):
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        out = roundtrip([("a", a)])
        assert out[0][0] == "a"
        np.testing.assert_array_equal(out[0][1], a)

    def test_f16_and_i32(self):
        h = np.asarray([1.5, -2.25], np.float16)
        i = np.asarray([[1, 2], [3, 4]], np.int32)
        out = dict(roundtrip([("h", h), ("i", i)]))
        np.testing.assert_array_equal(out["h"], h)
        np.testing.assert_array_equal(out["i"], i)
        assert out["h"].dtype == np.float16
        assert out["i"].dtype == np.int32

    def test_order_preserved(self):
        tensors = [(f"t{k}", np.full((2,), k, np.float32)) for k in range(20)]
        out = roundtrip(tensors)
        assert [n for n, _ in out] == [f"t{k}" for k in range(20)]

    def test_scalar_and_empty(self):
        out = dict(roundtrip([("s", np.float32(3.5).reshape(())),
                              ("e", np.zeros((0,), np.float32))]))
        assert out["s"].shape == ()
        assert float(out["s"]) == 3.5
        assert out["e"].size == 0

    def test_bad_magic_raises(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "bad.hwt")
            with open(path, "wb") as f:
                f.write(b"NOPE" + b"\x00" * 16)
            with pytest.raises(ValueError):
                hwt.load(path)

    def test_unsupported_dtype_raises(self):
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(ValueError):
                hwt.save(os.path.join(d, "x.hwt"),
                         [("x", np.zeros(3, np.float64))])
