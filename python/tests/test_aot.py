"""AOT lowering path: HLO text generation, operand lists, corpus determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, corpus, hss_np, model


class TestHloText:
    def test_small_fn_lowered_to_hlo_text(self):
        def f(x, y):
            return (jnp.matmul(x, y) + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        text = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
        assert "HloModule" in text
        assert "f32[4,4]" in text

    def test_pallas_kernel_lowered_to_plain_hlo(self):
        # interpret=True pallas must produce executable-anywhere HLO
        from compile.kernels.lowrank import lowrank_apply

        def f(u, r, x):
            return (lowrank_apply(u, r, x),)

        u = jax.ShapeDtypeStruct((16, 4), jnp.float32)
        r = jax.ShapeDtypeStruct((4, 16), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 8), jnp.float32)
        text = aot.to_hlo_text(jax.jit(f).lower(u, r, x))
        assert "HloModule" in text
        assert "custom-call" not in text.lower()  # no Mosaic — CPU-runnable


class TestOperandLists:
    def test_non_qkv_drops_projections(self):
        params = [(n, np.zeros((2, 2), np.float32))
                  for n in model.param_names()]
        kept = aot.non_qkv(params)
        names = [n for n, _ in kept]
        assert not any(n.endswith((".wq", ".wk", ".wv")) for n in names)
        # 12 projections dropped from the default 4-layer model
        assert len(params) - len(kept) == 12

    def test_flatten_skips_empty_sparse(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((64, 64))
        cfg = hss_np.HssConfig(rank=8, sparsity=0.1, depth=2, min_leaf=4)
        tree = hss_np.build(a, cfg)
        names = [n for n, arr in hss_np.flatten(tree, "w")]
        # root sparse present, child sparse absent (root-only default)
        assert "w.rows" in names
        assert "w.c0.rows" not in names

    def test_spec_nnz_matches_flatten(self):
        rng = np.random.default_rng(1)
        a = rng.standard_normal((64, 64))
        cfg = hss_np.HssConfig(rank=8, sparsity=0.2, depth=2, min_leaf=4)
        tree = hss_np.build(a, cfg)
        sp = hss_np.spec(tree)
        assert sp["nnz"] == int(0.2 * 64 * 64)
        assert sp["c0"]["nnz"] == 0


class TestCorpus:
    def test_deterministic(self):
        a = corpus.generate(5000, 123)
        b = corpus.generate(5000, 123)
        assert a == b

    def test_different_seeds_differ(self):
        assert corpus.generate(2000, 1) != corpus.generate(2000, 2)

    def test_ascii_only(self):
        text = corpus.generate(10_000, 7)
        assert all(ord(c) < 128 for c in text)

    def test_has_sentence_structure(self):
        text = corpus.generate(20_000, 9)
        assert text.count(".") > 50
        assert "the" in text.lower()
