"""L2 model tests: shapes, compressed-vs-dense agreement, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import hss_np, model

TINY = dict(model.CONFIG, d_model=64, n_heads=4, n_layers=2, d_ff=128,
            seq_len=32)


@pytest.fixture(scope="module")
def params():
    return model.init_params(0, TINY)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, TINY["vocab"], (2, TINY["seq_len"])),
                       jnp.int32)


def build_hss_for(params, cfg):
    specs, ops = {}, {}
    for i in range(TINY["n_layers"]):
        for p in ("wq", "wk", "wv"):
            name = f"layer{i}.{p}"
            tree = hss_np.build(np.asarray(params[name]).T.astype(np.float64),
                                cfg)
            specs[name] = hss_np.spec(tree)
            for n, a in hss_np.flatten(tree, name):
                ops[n] = jnp.asarray(a)
    return specs, ops


class TestDenseFwd:
    def test_logit_shape(self, params, tokens):
        logits = model.fwd(params, tokens, TINY)
        assert logits.shape == (2, TINY["seq_len"], TINY["vocab"])

    def test_causality(self, params, tokens):
        """Perturbing token t must not change logits before t."""
        logits = model.fwd(params, tokens, TINY)
        toks2 = tokens.at[0, 20].set((tokens[0, 20] + 1) % 256)
        logits2 = model.fwd(params, toks2, TINY)
        np.testing.assert_allclose(logits[0, :20], logits2[0, :20],
                                   rtol=1e-4, atol=1e-4)

    def test_pallas_vs_jnp_attention_paths_agree(self, params, tokens):
        a = model.fwd(params, tokens, TINY, use_pallas=True)
        b = model.fwd(params, tokens, TINY, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def test_param_names_match_shapes(self):
        names = model.param_names(TINY)
        shapes = model.param_shapes(TINY)
        assert set(names) == set(shapes)
        assert names[0] == "tok_emb"


class TestCompressedFwd:
    def test_near_exact_config_matches_dense(self, params, tokens):
        cfg = hss_np.HssConfig(rank=32, sparsity=0.3, depth=1, rsvd=False)
        hss = build_hss_for(params, cfg)
        dense = model.fwd(params, tokens, TINY)
        comp = model.fwd(params, tokens, TINY, hss=hss)
        np.testing.assert_allclose(comp, dense, rtol=1e-3, atol=1e-3)

    def test_lossy_config_close_in_distribution(self, params, tokens):
        cfg = hss_np.HssConfig(rank=8, sparsity=0.2, depth=2)
        hss = build_hss_for(params, cfg)
        dense = jax.nn.log_softmax(model.fwd(params, tokens, TINY))
        comp = jax.nn.log_softmax(model.fwd(params, tokens, TINY, hss=hss))
        # lossy, but the predictive distribution must stay in the same
        # ballpark (mean |delta log p| well under 1 nat for init weights)
        assert float(jnp.mean(jnp.abs(dense - comp))) < 1.0

    def test_depth3_runs(self, params, tokens):
        cfg = hss_np.HssConfig(rank=8, sparsity=0.1, depth=3, min_leaf=4)
        hss = build_hss_for(params, cfg)
        logits = model.fwd(params, tokens, TINY, hss=hss)
        assert logits.shape == (2, TINY["seq_len"], TINY["vocab"])
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestTraining:
    def test_loss_decreases(self):
        from compile import train as train_mod
        params = model.init_params(1, TINY)
        opt = train_mod.adam_init(params)
        step = train_mod.make_step(lr=1e-3, cfg=TINY)
        rng = np.random.default_rng(3)
        # single repeated batch: loss must drop fast if grads flow
        toks = jnp.asarray(rng.integers(0, 64, (4, TINY["seq_len"] + 1)),
                           jnp.int32)
        first = None
        for _ in range(30):
            params, opt, loss = step(params, opt, toks)
            first = first if first is not None else float(loss)
        assert float(loss) < first * 0.8

    def test_loss_fn_finite(self):
        params = model.init_params(2, TINY)
        rng = np.random.default_rng(4)
        toks = jnp.asarray(rng.integers(0, 256, (2, TINY["seq_len"] + 1)),
                           jnp.int32)
        assert np.isfinite(float(model.loss_fn(params, toks, TINY)))
