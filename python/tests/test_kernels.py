"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the batch-tile padding edge cases); fixed-seed
numpy provides the data. This is the core correctness signal for the kernels
that end up inside the AOT HLO graphs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention_apply
from compile.kernels.blockdiag import blockdiag_apply
from compile.kernels.lowrank import lowrank_apply
from compile.kernels.sparse_coo import sparse_coo_apply

RNG = np.random.default_rng(0xC0DE)
TOL = dict(rtol=2e-4, atol=2e-4)


def randf(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# blockdiag
# ---------------------------------------------------------------------------

class TestBlockDiag:
    def test_basic(self):
        d, x = randf(4, 32, 32), randf(4, 32, 16)
        np.testing.assert_allclose(blockdiag_apply(d, x),
                                   ref.blockdiag_ref(d, x), **TOL)

    def test_single_leaf(self):
        d, x = randf(1, 64, 64), randf(1, 64, 1)
        np.testing.assert_allclose(blockdiag_apply(d, x),
                                   ref.blockdiag_ref(d, x), **TOL)

    def test_batch_not_multiple_of_tile(self):
        d, x = randf(2, 16, 16), randf(2, 16, 200)  # 200 % 128 != 0
        np.testing.assert_allclose(blockdiag_apply(d, x, bt=128),
                                   ref.blockdiag_ref(d, x), **TOL)

    def test_identity_blocks(self):
        n = 16
        d = jnp.stack([jnp.eye(n)] * 3)
        x = randf(3, n, 5)
        np.testing.assert_allclose(blockdiag_apply(d, x), x, **TOL)

    @settings(max_examples=15, deadline=None)
    @given(l=st.integers(1, 6), n=st.sampled_from([8, 16, 32, 64]),
           b=st.integers(1, 40))
    def test_shapes_sweep(self, l, n, b):
        d, x = randf(l, n, n), randf(l, n, b)
        np.testing.assert_allclose(blockdiag_apply(d, x),
                                   ref.blockdiag_ref(d, x), **TOL)


# ---------------------------------------------------------------------------
# lowrank
# ---------------------------------------------------------------------------

class TestLowRank:
    def test_basic(self):
        u, r, x = randf(64, 8), randf(8, 96), randf(96, 33)
        np.testing.assert_allclose(lowrank_apply(u, r, x),
                                   ref.lowrank_ref(u, r, x), **TOL)

    def test_rank_one(self):
        u, r, x = randf(32, 1), randf(1, 32), randf(32, 7)
        np.testing.assert_allclose(lowrank_apply(u, r, x),
                                   ref.lowrank_ref(u, r, x), **TOL)

    def test_rectangular(self):
        u, r, x = randf(128, 16), randf(16, 64), randf(64, 130)
        np.testing.assert_allclose(lowrank_apply(u, r, x),
                                   ref.lowrank_ref(u, r, x), **TOL)

    @settings(max_examples=15, deadline=None)
    @given(m=st.sampled_from([16, 32, 64]), k=st.integers(1, 16),
           n=st.sampled_from([16, 32, 64]), b=st.integers(1, 40))
    def test_shapes_sweep(self, m, k, n, b):
        u, r, x = randf(m, k), randf(k, n), randf(n, b)
        np.testing.assert_allclose(lowrank_apply(u, r, x),
                                   ref.lowrank_ref(u, r, x), **TOL)


# ---------------------------------------------------------------------------
# sparse_coo
# ---------------------------------------------------------------------------

def rand_coo(k, n):
    rows = jnp.asarray(RNG.integers(0, n, k), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, n, k), jnp.int32)
    vals = jnp.asarray(RNG.standard_normal(k), jnp.float32)
    return rows, cols, vals


class TestSparseCoo:
    def test_basic(self):
        n = 64
        rows, cols, vals = rand_coo(100, n)
        x = randf(n, 17)
        np.testing.assert_allclose(
            sparse_coo_apply(rows, cols, vals, x, n),
            ref.sparse_coo_ref(rows, cols, vals, x, n), **TOL)

    def test_empty(self):
        n = 16
        z = jnp.zeros(0, jnp.int32)
        out = sparse_coo_apply(z, z, jnp.zeros(0, jnp.float32), randf(n, 3), n)
        np.testing.assert_allclose(out, np.zeros((n, 3)), **TOL)

    def test_duplicate_entries_accumulate(self):
        n = 8
        rows = jnp.asarray([2, 2, 2], jnp.int32)
        cols = jnp.asarray([3, 3, 3], jnp.int32)
        vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
        x = jnp.zeros((n, 1), jnp.float32).at[3, 0].set(1.0)
        out = sparse_coo_apply(rows, cols, vals, x, n)
        assert float(out[2, 0]) == pytest.approx(6.0)

    def test_zero_padding_contributes_nothing(self):
        n = 16
        rows = jnp.asarray([1, 0, 0], jnp.int32)
        cols = jnp.asarray([1, 0, 0], jnp.int32)
        vals = jnp.asarray([5.0, 0.0, 0.0], jnp.float32)
        x = jnp.ones((n, 2), jnp.float32)
        out = sparse_coo_apply(rows, cols, vals, x, n)
        expect = np.zeros((n, 2)); expect[1] = 5.0
        np.testing.assert_allclose(out, expect, **TOL)

    @settings(max_examples=15, deadline=None)
    @given(k=st.integers(1, 200), n=st.sampled_from([16, 32, 64]),
           b=st.integers(1, 20))
    def test_shapes_sweep(self, k, n, b):
        rows, cols, vals = rand_coo(k, n)
        x = randf(n, b)
        np.testing.assert_allclose(
            sparse_coo_apply(rows, cols, vals, x, n),
            ref.sparse_coo_ref(rows, cols, vals, x, n), **TOL)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class TestAttention:
    def test_basic(self):
        q, k, v = randf(4, 32, 16), randf(4, 32, 16), randf(4, 32, 16)
        expect = jax.vmap(ref.attention_ref)(q, k, v)
        np.testing.assert_allclose(attention_apply(q, k, v), expect, **TOL)

    def test_causality(self):
        """Changing future keys/values must not change earlier outputs."""
        q, k, v = randf(1, 16, 8), randf(1, 16, 8), randf(1, 16, 8)
        base = attention_apply(q, k, v)
        k2 = k.at[0, 10:].add(100.0)
        v2 = v.at[0, 10:].add(100.0)
        pert = attention_apply(q, k2, v2)
        np.testing.assert_allclose(base[0, :10], pert[0, :10], **TOL)

    def test_softmax_rows_via_uniform_v(self):
        """With V = ones, output must be exactly ones (rows sum to 1)."""
        q, k = randf(2, 12, 8), randf(2, 12, 8)
        v = jnp.ones((2, 12, 8), jnp.float32)
        np.testing.assert_allclose(attention_apply(q, k, v),
                                   np.ones((2, 12, 8)), **TOL)

    @settings(max_examples=10, deadline=None)
    @given(bh=st.integers(1, 6), t=st.sampled_from([8, 16, 64, 128]),
           hd=st.sampled_from([8, 16, 32]))
    def test_shapes_sweep(self, bh, t, hd):
        q, k, v = randf(bh, t, hd), randf(bh, t, hd), randf(bh, t, hd)
        expect = jax.vmap(ref.attention_ref)(q, k, v)
        np.testing.assert_allclose(attention_apply(q, k, v), expect, **TOL)
