"""HWT — the hisolo weight/tensor interchange format (python side).

A deliberately simple little-endian binary container shared with the Rust
reader (`rust/src/model/weights.rs`). Layout:

    magic   b"HWT1"
    u32     n_tensors
    repeat n_tensors times:
        u32                 name_len
        name_len bytes      utf-8 name
        u8                  dtype (0 = f32, 1 = f16, 2 = i32)
        u32                 ndim
        ndim * u32          dims
        prod(dims) * size   raw data, little endian, C order

Names are ordered; the order in the file defines the operand order for AOT
executables (mirrored in artifacts/manifest.json).
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"HWT1"
DTYPES = {0: np.float32, 1: np.float16, 2: np.int32}
DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.float16): 1, np.dtype(np.int32): 2}


def save(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            # np.ascontiguousarray would promote 0-d to 1-d; asarray keeps rank
            arr = np.asarray(arr, order="C")
            if arr.dtype not in DTYPE_CODES:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", DTYPE_CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for name, arr in load_ordered(path):
        out[name] = arr
    return out


def load_ordered(path: str) -> List[Tuple[str, np.ndarray]]:
    out: List[Tuple[str, np.ndarray]] = []
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(DTYPES[code])
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(count * dt.itemsize), dtype=dt)
            out.append((name, data.reshape(dims)))
    return out
