"""Train the substitute byte-level LM (build path only).

Adam from scratch (no optax offline), a few hundred steps on the synthetic
corpus — enough for the q/k/v projections to acquire the trained structure
(magnitude spikes + off-diagonal low-rankness) the compression methods
exploit. Weights land in artifacts/model.hwt in the canonical operand order.

Usage: python -m compile.train --out ../artifacts [--steps 400]
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, hwt, model


def load_tokens(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        return np.frombuffer(f.read(), dtype=np.uint8).astype(np.int32)


def batches(tokens: np.ndarray, batch: int, seq: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, batch)
        yield np.stack([tokens[i:i + seq + 1] for i in idx])


def adam_init(params: Dict[str, jax.Array]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def make_step(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.99,
              eps: float = 1e-8, clip: float = 1.0, cfg=None,
              weight_decay: float = 0.05):
    """AdamW. The decoupled weight decay matters for the reproduction: it
    induces the low-rank structure in trained projections that the paper's
    LLaMA-7B weights exhibit (and that sHSS exploits)."""
    cfg = model.CONFIG if cfg is None else cfg

    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, tokens, cfg)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, clip / (gnorm + 1e-12))
        t = opt["t"] + 1
        tf = t.astype(jnp.float32)
        new_m, new_v, new_p = {}, {}, {}
        for k, g in grads.items():
            g = g * scale
            m = b1 * opt["m"][k] + (1 - b1) * g
            v = b2 * opt["v"][k] + (1 - b2) * g * g
            mhat = m / (1 - b1 ** tf)
            vhat = v / (1 - b2 ** tf)
            # decay only matrix weights (not gains/biases/embeddings)
            wd = weight_decay if (k.split(".")[-1].startswith("w")) else 0.0
            new_p[k] = params[k] - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                         + wd * params[k])
            new_m[k], new_v[k] = m, v
        return new_p, {"m": new_m, "v": new_v, "t": t}, loss

    return step


def eval_ppl(params, tokens: np.ndarray, batch: int, seq: int,
             n_batches: int = 8, seed: int = 7) -> float:
    it = batches(tokens, batch, seq, seed)
    losses = []
    lf = jax.jit(model.loss_fn)
    for _ in range(n_batches):
        losses.append(float(lf(params, jnp.asarray(next(it)))))
    return float(np.exp(np.mean(losses)))


def train(out_dir: str, steps: int = 400, batch: int = 16, seed: int = 0,
          log_every: int = 50) -> Dict[str, np.ndarray]:
    corpus.write_splits(out_dir)
    seq = model.CONFIG["seq_len"]
    train_toks = load_tokens(os.path.join(out_dir, "corpus_train.txt"))
    valid_toks = load_tokens(os.path.join(out_dir, "corpus_valid.txt"))

    params = model.init_params(seed)
    opt = adam_init(params)
    step = make_step()
    it = batches(train_toks, batch, seq, seed + 1)

    t0 = time.time()
    for s in range(1, steps + 1):
        params, opt, loss = step(params, opt, jnp.asarray(next(it)))
        if s % log_every == 0 or s == 1:
            print(f"step {s:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
    vppl = eval_ppl(params, valid_toks, batch, seq)
    print(f"train done: valid ppl (byte-level) = {vppl:.4f}")

    np_params = {k: np.asarray(v) for k, v in params.items()}
    path = os.path.join(out_dir, "model.hwt")
    hwt.save(path, [(n, np_params[n]) for n in model.param_names()])
    print(f"saved weights to {path}")
    return np_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    path = os.path.join(args.out, "model.hwt")
    if os.path.exists(path) and not args.force:
        print(f"train: {path} exists, skipping (use --force to retrain)")
        return
    train(args.out, steps=args.steps, batch=args.batch)


if __name__ == "__main__":
    main()
