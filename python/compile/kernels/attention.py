"""Pallas kernel: causal multi-head attention for the L2 transformer.

One grid step per (batch*head); each step keeps the full [t, hd] q/k/v
panels in VMEM (t=128, hd=32 at our scale: 3 * 128*32*2B = 24 KiB, far under
budget) and runs the two MXU matmuls plus a fused masked softmax. At larger t
this would tile over key blocks flash-style; for the reproduction scale a
single-panel kernel is the right structure and keeps interpret-mode runtime
reasonable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref):
    # q,k,v: [1, t, hd] -> o: [1, t, hd]
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=q.dtype) * scale
    ri = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(ri >= ci, scores, jnp.finfo(scores.dtype).min)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=q.dtype)


@jax.jit
def attention_apply(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal MHA. q,k,v: [bh, t, hd] -> [bh, t, hd]."""
    bh, t, hd = q.shape
    spec = pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, hd), q.dtype),
        interpret=True,
    )(q, k, v)


def vmem_bytes(t: int, hd: int, itemsize: int = 2) -> int:
    """Per-step VMEM: q, k, v, out panels + scores/probs buffer."""
    return itemsize * (4 * t * hd + 2 * t * t)
