"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each `*_ref` implements exactly the contract of the corresponding kernel in
this package, with no Pallas involvement; pytest asserts allclose between the
two across shape/dtype sweeps (see python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_matvec_ref(d: jax.Array, x: jax.Array) -> jax.Array:
    """Y = D @ X for a dense square block. d: [n, n], x: [n, b]."""
    return d @ x


def blockdiag_ref(d: jax.Array, x: jax.Array) -> jax.Array:
    """Block-diagonal apply. d: [L, n, n], x: [L, n, b] -> [L, n, b]."""
    return jnp.einsum("lij,ljb->lib", d, x)


def lowrank_ref(u: jax.Array, r: jax.Array, x: jax.Array) -> jax.Array:
    """Thin coupling Y = U @ (R @ X). u: [m, k], r: [k, n], x: [n, b]."""
    return u @ (r @ x)


def sparse_coo_ref(rows: jax.Array, cols: jax.Array, vals: jax.Array,
                   x: jax.Array, n_out: int) -> jax.Array:
    """Fixed-capacity COO apply: Y[rows[k]] += vals[k] * X[cols[k], :].

    Padding entries carry vals == 0 (rows/cols point at slot 0), so they
    contribute nothing. x: [n_in, b] -> [n_out, b].
    """
    contrib = vals[:, None] * x[cols, :]
    return jax.ops.segment_sum(contrib, rows, num_segments=n_out)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal single-head attention. q,k,v: [t, hd] -> [t, hd]."""
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    return jax.nn.softmax(scores, axis=-1) @ v
