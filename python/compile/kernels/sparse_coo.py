"""Pallas kernel: fixed-capacity COO sparse apply.

The sHSS decomposition carves the top-p% magnitude "spikes" into a sparse
matrix S applied as y += S @ x. XLA (and TPUs) want static shapes, so S is
stored at a fixed capacity K (= the sparsity budget) as (rows, cols, vals),
zero-padded; padding entries have vals == 0 and contribute nothing.

TPU adaptation (DESIGN.md §8): GPUs would scatter with atomics; TPUs have
none, so entries are row-sorted at build time and applied as
gather(x)[cols] * vals followed by a segment-sum over rows — linear memory
traffic, fully vectorised, no data-dependent shapes.

The kernel grid runs over batch tiles; rows/cols/vals are small enough
(K <= a few thousand) to stay VMEM-resident across steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 128


def _kernel(rows_ref, cols_ref, vals_ref, x_ref, o_ref, *, n_out: int):
    rows = rows_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...]
    x = x_ref[...]                      # [n_in, bt]
    contrib = vals[:, None] * x[cols, :]  # [K, bt] gather
    o_ref[...] = jax.ops.segment_sum(contrib, rows, num_segments=n_out)


@functools.partial(jax.jit, static_argnames=("n_out", "bt"))
def sparse_coo_apply(rows: jax.Array, cols: jax.Array, vals: jax.Array,
                     x: jax.Array, n_out: int, bt: int = DEFAULT_BT) -> jax.Array:
    """Y[rows[k]] += vals[k] * X[cols[k], :].  x: [n_in, b] -> [n_out, b]."""
    kcap = rows.shape[0]
    n_in, b = x.shape
    if kcap == 0:
        return jnp.zeros((n_out, b), x.dtype)
    bt = min(bt, b)
    pad = (-b) % bt
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    bp = x.shape[1]
    out = pl.pallas_call(
        functools.partial(_kernel, n_out=n_out),
        grid=(bp // bt,),
        in_specs=[
            pl.BlockSpec((kcap,), lambda j: (0,)),
            pl.BlockSpec((kcap,), lambda j: (0,)),
            pl.BlockSpec((kcap,), lambda j: (0,)),
            pl.BlockSpec((n_in, bt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_out, bt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, bp), x.dtype),
        interpret=True,
    )(rows, cols, vals, x)
    return out[:, :b] if pad else out


def vmem_bytes(kcap: int, n_in: int, n_out: int, bt: int = DEFAULT_BT,
               itemsize: int = 2) -> int:
    """Per-step VMEM: index/value triple + x tile + contrib + out tile."""
    return 4 * 2 * kcap + itemsize * (kcap + n_in * bt + kcap * bt + n_out * bt)
