"""Pallas kernel: fused thin low-rank coupling Y = U @ (R @ X).

The off-diagonal HSS couplings are rank-r with r << n. A naive
implementation materialises T = R @ X in HBM and reads it back; the fused
kernel keeps T in a VMEM scratch buffer so X is touched once and T never
leaves the core — the TPU analogue of the paper's "sequence of thin-matrix
multiplications" staying in registers/smem on the GPU.

MXU note: r is zero-padded to the 128-lane width by the compiler; for the
paper's rank schedule (outer rank >= 64 after scaling) utilization stays
>= 50%. interpret=True for CPU-PJRT executability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 128


def _kernel(u_ref, r_ref, x_ref, o_ref):
    # u: [m, k], r: [k, n], x: [n, bt], o: [m, bt].  The intermediate
    # t = R @ x stays a kernel-local value (VMEM), never round-trips HBM.
    t = jnp.dot(r_ref[...], x_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] = jnp.dot(u_ref[...], t, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt",))
def lowrank_apply(u: jax.Array, r: jax.Array, x: jax.Array,
                  bt: int = DEFAULT_BT) -> jax.Array:
    """Y = U @ (R @ X).  u: [m, k], r: [k, n], x: [n, b] -> [m, b]."""
    m, k = u.shape
    n = r.shape[1]
    b = x.shape[1]
    bt = min(bt, b)
    pad = (-b) % bt
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    bp = x.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(bp // bt,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, n), lambda j: (0, 0)),
            pl.BlockSpec((n, bt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, bp), x.dtype),
        interpret=True,
    )(u, r, x)
    return out[:, :b] if pad else out


def vmem_bytes(m: int, k: int, n: int, bt: int = DEFAULT_BT, itemsize: int = 2) -> int:
    """VMEM per grid step: U + R + x tile + scratch T + out tile."""
    return itemsize * (m * k + k * n + n * bt + k * bt + m * bt)
