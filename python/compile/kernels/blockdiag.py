"""Pallas kernel: block-diagonal matmul over the HSS leaf blocks.

This is the dense hot-spot of the sHSS matvec — at the deepest level the
residual matrix is a block-diagonal collection of L small dense blocks D_i,
and Y[l] = D[l] @ X[l] for every leaf simultaneously.

TPU mapping (see DESIGN.md §8): one grid step per (leaf, batch-tile); the
BlockSpec keeps a full n×n leaf plus an n×bt activation tile resident in
VMEM and drives the MXU with a single (n,n)x(n,bt) matmul per step. Leaves
are streamed HBM→VMEM in grid order, which is the TPU analogue of the
paper's one-threadblock-per-block CUDA schedule.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (same numerics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: multiples of the 128-lane MXU width. For CPU interpret mode the
# value only affects structure, not wallclock fidelity.
DEFAULT_BT = 128


def _kernel(d_ref, x_ref, o_ref):
    # d_ref: [1, n, n], x_ref: [1, n, bt], o_ref: [1, n, bt]
    o_ref[0] = jnp.dot(d_ref[0], x_ref[0], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt",))
def blockdiag_apply(d: jax.Array, x: jax.Array, bt: int = DEFAULT_BT) -> jax.Array:
    """Y[l] = D[l] @ X[l].  d: [L, n, n], x: [L, n, b] -> [L, n, b]."""
    l, n, _ = d.shape
    b = x.shape[2]
    bt = min(bt, b)
    pad = (-b) % bt
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    bp = x.shape[2]
    out = pl.pallas_call(
        _kernel,
        grid=(l, bp // bt),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n, bt), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, n, bt), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((l, n, bp), x.dtype),
        interpret=True,
    )(d, x)
    return out[:, :, :b] if pad else out


def vmem_bytes(n: int, bt: int = DEFAULT_BT, itemsize: int = 2) -> int:
    """Estimated VMEM residency per grid step (leaf + in tile + out tile)."""
    return itemsize * (n * n + 2 * n * bt)
