"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

Emits, under artifacts/:
    model_dense_b{1,8}.hlo.txt  dense forward      (tokens + params -> logits)
    model_hss_b{1,8}.hlo.txt    compressed forward (tokens + params + hss ops)
    model.hwt                   trained weights (from compile.train)
    hss_operands.hwt            flattened sHSS-RCM operands, canonical order
    manifest.json               operand order/shapes per executable

HLO **text** is the interchange format, not `.serialize()`: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version the
`xla` crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import hss_np, hwt, model, train

QKV = ("wq", "wk", "wv")
SERVE_BATCHES = (1, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(a: np.ndarray) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
            np.dtype(np.float16): "f16"}[a.dtype]


def _input_list(named: List[Tuple[str, np.ndarray]]) -> List[Dict]:
    return [{"name": n, "dtype": _dtype_name(a), "shape": list(a.shape)}
            for n, a in named]


def build_hss(params: Dict[str, np.ndarray], cfg: hss_np.HssConfig):
    """Compress every q/k/v projection (as W^T — see model.hss_project)."""
    specs: Dict[str, Dict] = {}
    ops: List[Tuple[str, np.ndarray]] = []
    for i in range(model.CONFIG["n_layers"]):
        for p in QKV:
            name = f"layer{i}.{p}"
            tree = hss_np.build(params[name].T.astype(np.float64), cfg)
            specs[name] = hss_np.spec(tree)
            ops.extend(hss_np.flatten(tree, name))
    return specs, ops


def lower_dense(params_named: List[Tuple[str, np.ndarray]], batch: int) -> str:
    seq = model.CONFIG["seq_len"]
    names = [n for n, _ in params_named]

    def f(tokens, *flat):
        p = dict(zip(names, flat))
        return (model.fwd(p, tokens),)

    args = [jax.ShapeDtypeStruct((batch, seq), jnp.int32)]
    args += [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in params_named]
    return to_hlo_text(jax.jit(f).lower(*args))


def non_qkv(params_named: List[Tuple[str, np.ndarray]]) -> List[Tuple[str, np.ndarray]]:
    """Drop wq/wk/wv — the compressed graph replaces them, and JAX prunes
    unused arguments at lowering time (so they must not be in the operand
    list either)."""
    return [(n, a) for n, a in params_named
            if not n.endswith((".wq", ".wk", ".wv"))]


def lower_hss(params_named: List[Tuple[str, np.ndarray]],
              specs: Dict[str, Dict], ops_named: List[Tuple[str, np.ndarray]],
              batch: int) -> str:
    seq = model.CONFIG["seq_len"]
    params_named = non_qkv(params_named)
    pnames = [n for n, _ in params_named]
    onames = [n for n, _ in ops_named]
    n_params = len(pnames)

    def f(tokens, *flat):
        p = dict(zip(pnames, flat[:n_params]))
        o = dict(zip(onames, flat[n_params:]))
        return (model.fwd(p, tokens, hss=(specs, o)),)

    args = [jax.ShapeDtypeStruct((batch, seq), jnp.int32)]
    args += [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in params_named]
    args += [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in ops_named]
    return to_hlo_text(jax.jit(f).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--sparsity", type=float, default=0.30)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--no-rcm", action="store_true")
    ap.add_argument("--train-steps", type=int, default=400)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    wpath = os.path.join(out, "model.hwt")
    if not os.path.exists(wpath):
        train.train(out, steps=args.train_steps)
    params_named = hwt.load_ordered(wpath)
    params = dict(params_named)
    assert [n for n, _ in params_named] == model.param_names(), "operand order drift"

    cfg = hss_np.HssConfig(rank=args.rank, sparsity=args.sparsity,
                           depth=args.depth, use_rcm=not args.no_rcm)
    print(f"aot: building sHSS{'-RCM' if cfg.use_rcm else ''} operands "
          f"(rank={cfg.rank} sp={cfg.sparsity} depth={cfg.depth})", flush=True)
    specs, ops_named = build_hss(params, cfg)
    hwt.save(os.path.join(out, "hss_operands.hwt"), ops_named)

    # Dense params the compressed graph still consumes (wq/wk/wv are unused
    # inside the traced fn but kept in the operand list so both executables
    # share one feeding order — rust passes the same weight file to both).
    manifest = {
        "model_config": model.CONFIG,
        "hss_config": {"rank": cfg.rank, "sparsity": cfg.sparsity,
                       "depth": cfg.depth, "use_rcm": cfg.use_rcm,
                       "tol": cfg.tol},
        "executables": {},
    }

    for b in SERVE_BATCHES:
        name = f"model_dense_b{b}"
        path = os.path.join(out, f"{name}.hlo.txt")
        print(f"aot: lowering {name}", flush=True)
        with open(path, "w") as f:
            f.write(lower_dense(params_named, b))
        manifest["executables"][name] = {
            "file": f"{name}.hlo.txt",
            "batch": b,
            "inputs": ([{"name": "tokens", "dtype": "i32",
                         "shape": [b, model.CONFIG["seq_len"]]}]
                       + _input_list(params_named)),
            "output": {"dtype": "f32",
                       "shape": [b, model.CONFIG["seq_len"],
                                 model.CONFIG["vocab"]]},
        }

    for b in SERVE_BATCHES:
        name = f"model_hss_b{b}"
        path = os.path.join(out, f"{name}.hlo.txt")
        print(f"aot: lowering {name}", flush=True)
        with open(path, "w") as f:
            f.write(lower_hss(params_named, specs, ops_named, b))
        manifest["executables"][name] = {
            "file": f"{name}.hlo.txt",
            "batch": b,
            "inputs": ([{"name": "tokens", "dtype": "i32",
                         "shape": [b, model.CONFIG["seq_len"]]}]
                       + _input_list(non_qkv(params_named))
                       + _input_list(ops_named)),
            "output": {"dtype": "f32",
                       "shape": [b, model.CONFIG["seq_len"],
                                 model.CONFIG["vocab"]]},
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("aot: wrote manifest.json")


if __name__ == "__main__":
    main()
