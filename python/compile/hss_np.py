"""Build-time sHSS / sHSS-RCM compression pipeline (numpy).

Mirrors the Rust-native implementation in `rust/src/hss/` (the runtime path);
this copy exists so `aot.py` can bake a compressed model into an AOT HLO
graph, and so the two independent implementations cross-validate each other
in tests.

Algorithm (paper §4.5, Algorithm 1), per node at every recursion level:
  1. carve the top-p% magnitude entries of the current block into a COO
     sparse matrix S (fixed capacity => static shapes for XLA),
  2. optionally RCM-reorder the residual (symmetrized magnitude pattern) so
     large entries concentrate near the diagonal,
  3. split 2x2; truncated (randomized) SVD of the off-diagonal blocks at the
     level's rank; halve the rank and recurse into the diagonal blocks.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import reverse_cuthill_mckee


@dataclass
class HssConfig:
    rank: int = 32              # outer rank (halved each level, floor 1)
    sparsity: float = 0.1       # fraction of entries carved into S
    # True re-extracts top-p% at every level (§4.5's literal reading —
    # ablation only, inflates storage); default False = one S at the root,
    # matching the paper's storage numbers and the Rust default.
    sparse_per_level: bool = False
    depth: int = 3              # number of split levels (leaves at n / 2**depth)
    tol: float = 1e-6           # singular values below tol are dropped
    use_rcm: bool = True
    min_leaf: int = 16          # stop splitting below this block size
    pattern_quantile: float = 0.90  # |R| quantile defining the RCM graph
    rsvd: bool = True           # randomized SVD for the off-diagonal factors
    oversample: int = 8
    power_iters: int = 1
    seed: int = 0


@dataclass
class HssNode:
    n: int
    # fixed-capacity COO of this level's spikes, in this node's coordinates
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    perm: np.ndarray                       # int32 [n]; residual_p = R[perm][:, perm]
    leaf: Optional[np.ndarray] = None      # dense block if this is a leaf
    u0: Optional[np.ndarray] = None        # A12 ~ u0 @ r0   (n0 x k)(k x n1)
    r0: Optional[np.ndarray] = None
    u1: Optional[np.ndarray] = None        # A21 ~ u1 @ r1   (n1 x k)(k x n0)
    r1: Optional[np.ndarray] = None
    child0: Optional["HssNode"] = None
    child1: Optional["HssNode"] = None

    @property
    def is_leaf(self) -> bool:
        return self.leaf is not None


def top_p_coo(a: np.ndarray, p: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Top-p% magnitude entries as row-sorted COO with exact capacity."""
    n2 = a.size
    k = int(np.floor(p * n2))
    if k == 0:
        z = np.zeros(0)
        return z.astype(np.int32), z.astype(np.int32), z.astype(np.float32)
    flat = np.abs(a).ravel()
    idx = np.argpartition(flat, n2 - k)[n2 - k:]
    idx = idx[np.argsort(idx)]           # row-major order == row-sorted
    rows = (idx // a.shape[1]).astype(np.int32)
    cols = (idx % a.shape[1]).astype(np.int32)
    vals = a.ravel()[idx].astype(np.float32)
    return rows, cols, vals


def coo_to_dense(rows, cols, vals, shape) -> np.ndarray:
    s = np.zeros(shape, dtype=np.float64)
    np.add.at(s, (rows, cols), vals)
    return s


def rcm_permutation(r: np.ndarray, quantile: float) -> np.ndarray:
    """RCM ordering of the symmetrized magnitude pattern of the residual."""
    n = r.shape[0]
    mag = np.abs(r)
    thresh = np.quantile(mag, quantile)
    pattern = mag >= max(thresh, 1e-30)
    pattern = pattern | pattern.T
    np.fill_diagonal(pattern, True)
    graph = csr_matrix(pattern.astype(np.int8))
    perm = reverse_cuthill_mckee(graph, symmetric_mode=True)
    return np.asarray(perm, dtype=np.int32)


def _truncated_svd(a: np.ndarray, k: int, tol: float) -> Tuple[np.ndarray, np.ndarray]:
    u, s, vt = np.linalg.svd(a, full_matrices=False)
    k = min(k, int(np.sum(s > tol)))
    k = max(k, 1)
    sq = np.sqrt(s[:k])
    return (u[:, :k] * sq[None, :]).astype(np.float32), (sq[:, None] * vt[:k]).astype(np.float32)


def _randomized_svd(a: np.ndarray, k: int, tol: float, oversample: int,
                    power_iters: int, rng: np.random.Generator
                    ) -> Tuple[np.ndarray, np.ndarray]:
    m, n = a.shape
    l = min(k + oversample, min(m, n))
    omega = rng.standard_normal((n, l))
    y = a @ omega
    for _ in range(power_iters):
        y, _ = np.linalg.qr(a @ (a.T @ y))
    q, _ = np.linalg.qr(y)
    b = q.T @ a
    ub, s, vt = np.linalg.svd(b, full_matrices=False)
    kk = max(1, min(k, int(np.sum(s > tol))))
    sq = np.sqrt(s[:kk])
    u = q @ ub[:, :kk]
    return (u * sq[None, :]).astype(np.float32), (sq[:, None] * vt[:kk]).astype(np.float32)


def build(a: np.ndarray, cfg: HssConfig, _depth: Optional[int] = None,
          _rank: Optional[int] = None, _rng: Optional[np.random.Generator] = None
          ) -> HssNode:
    """Recursively build the sparse-plus-HSS tree for square matrix `a`."""
    assert a.shape[0] == a.shape[1], "HSS requires square blocks"
    n = a.shape[0]
    depth = cfg.depth if _depth is None else _depth
    rank = cfg.rank if _rank is None else _rank
    rng = np.random.default_rng(cfg.seed) if _rng is None else _rng

    if depth == 0 or n // 2 < cfg.min_leaf:
        e = np.zeros(0)
        return HssNode(n=n, rows=e.astype(np.int32), cols=e.astype(np.int32),
                       vals=e.astype(np.float32),
                       perm=np.arange(n, dtype=np.int32),
                       leaf=a.astype(np.float32))

    is_root = _depth is None or _depth == cfg.depth
    p = cfg.sparsity if (is_root or cfg.sparse_per_level) else 0.0
    rows, cols, vals = top_p_coo(a, p)
    resid = a - coo_to_dense(rows, cols, vals, a.shape)
    if cfg.use_rcm:
        perm = rcm_permutation(resid, cfg.pattern_quantile)
    else:
        perm = np.arange(n, dtype=np.int32)
    rp = resid[np.ix_(perm, perm)]

    n0 = n // 2
    a11, a12 = rp[:n0, :n0], rp[:n0, n0:]
    a21, a22 = rp[n0:, :n0], rp[n0:, n0:]
    k = max(1, rank)
    if cfg.rsvd:
        u0, r0 = _randomized_svd(a12, k, cfg.tol, cfg.oversample, cfg.power_iters, rng)
        u1, r1 = _randomized_svd(a21, k, cfg.tol, cfg.oversample, cfg.power_iters, rng)
    else:
        u0, r0 = _truncated_svd(a12, k, cfg.tol)
        u1, r1 = _truncated_svd(a21, k, cfg.tol)

    child_rank = max(1, rank // 2)
    return HssNode(
        n=n, rows=rows, cols=cols, vals=vals, perm=perm,
        u0=u0, r0=r0, u1=u1, r1=r1,
        child0=build(a11, cfg, depth - 1, child_rank, rng),
        child1=build(a22, cfg, depth - 1, child_rank, rng),
    )


def apply(node: HssNode, x: np.ndarray) -> np.ndarray:
    """y = A_hss @ x for column-batched x [n, b] (numpy reference)."""
    if node.is_leaf:
        return node.leaf.astype(np.float64) @ x
    ys = np.zeros_like(x, dtype=np.float64)
    if node.vals.size:
        np.add.at(ys, node.rows, node.vals[:, None].astype(np.float64) * x[node.cols])
    xp = x[node.perm]
    n0 = node.n // 2
    x0, x1 = xp[:n0], xp[n0:]
    y0 = apply(node.child0, x0) + node.u0.astype(np.float64) @ (node.r0.astype(np.float64) @ x1)
    y1 = apply(node.child1, x1) + node.u1.astype(np.float64) @ (node.r1.astype(np.float64) @ x0)
    yh = np.concatenate([y0, y1], axis=0)
    y = np.empty_like(yh)
    y[node.perm] = yh
    return ys + y


def reconstruct(node: HssNode) -> np.ndarray:
    """Dense matrix represented by the tree (testing/verification only)."""
    if node.is_leaf:
        return node.leaf.astype(np.float64)
    n0 = node.n // 2
    rp = np.zeros((node.n, node.n))
    rp[:n0, :n0] = reconstruct(node.child0)
    rp[n0:, n0:] = reconstruct(node.child1)
    rp[:n0, n0:] = node.u0 @ node.r0
    rp[n0:, :n0] = node.u1 @ node.r1
    resid = np.empty_like(rp)
    resid[np.ix_(node.perm, node.perm)] = rp
    return coo_to_dense(node.rows, node.cols, node.vals, (node.n, node.n)) + resid


def storage_params(node: HssNode) -> int:
    """Number of stored parameters (matching the Rust accounting)."""
    if node.is_leaf:
        return node.leaf.size
    own = node.vals.size + node.u0.size + node.r0.size + node.u1.size + node.r1.size
    return own + storage_params(node.child0) + storage_params(node.child1)


def flatten(node: HssNode, prefix: str) -> List[Tuple[str, np.ndarray]]:
    """Deterministic (name, array) traversal used for AOT operand order."""
    out: List[Tuple[str, np.ndarray]] = []
    if node.is_leaf:
        out.append((f"{prefix}.leaf", node.leaf))
        return out
    if node.vals.size:  # empty triples would be pruned by jax at lowering
        out.append((f"{prefix}.rows", node.rows))
        out.append((f"{prefix}.cols", node.cols))
        out.append((f"{prefix}.vals", node.vals))
    out.append((f"{prefix}.perm", node.perm))
    for nm in ("u0", "r0", "u1", "r1"):
        out.append((f"{prefix}.{nm}", getattr(node, nm)))
    out.extend(flatten(node.child0, prefix + ".c0"))
    out.extend(flatten(node.child1, prefix + ".c1"))
    return out


def spec(node: HssNode) -> Dict:
    """Static structure description (shapes only) for rebuilding at trace time."""
    if node.is_leaf:
        return {"n": node.n, "leaf": True}
    return {
        "n": node.n,
        "leaf": False,
        "nnz": int(node.vals.size),
        "k0": int(node.u0.shape[1]),
        "k1": int(node.u1.shape[1]),
        "c0": spec(node.child0),
        "c1": spec(node.child1),
    }
