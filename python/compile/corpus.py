"""Deterministic synthetic corpus generator (build path).

The paper evaluates perplexity on WikiText-103; offline we substitute a
synthetic English-like corpus with learnable structure: a small probabilistic
grammar over templated sentences (subject/verb/object agreement, numbers,
punctuation, topic persistence within paragraphs). A byte-level LM trained on
it reaches a clearly sub-uniform perplexity, giving the compression methods a
non-trivial signal to preserve — which is what the storage-vs-PPL comparison
needs (method *ordering*, not absolute WikiText PPL, is the reproduced claim).

Usage: python -m compile.corpus --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

# Deterministic PRNG (splitmix64) so the corpus is reproducible and the Rust
# side can regenerate identical benchmark workloads if needed.
MASK = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, seq):
        return seq[self.below(len(seq))]

    def uniform(self) -> float:
        return self.next_u64() / float(1 << 64)


SUBJECTS = [
    "the model", "a transformer", "the matrix", "the encoder", "a researcher",
    "the gradient", "the network", "an attention head", "the optimizer",
    "the dataset", "a sparse block", "the low rank factor", "the scheduler",
    "the compiler", "a permutation", "the residual", "the kernel",
]
VERBS = [
    "compresses", "approximates", "projects", "factorizes", "reorders",
    "multiplies", "reduces", "preserves", "updates", "evaluates", "encodes",
    "partitions", "truncates", "scales", "permutes", "accumulates",
]
OBJECTS = [
    "the weight matrix", "the hidden state", "the attention scores",
    "the singular values", "the diagonal block", "the sparse residual",
    "the token embedding", "the key projection", "the value projection",
    "the off diagonal block", "the query projection", "the loss surface",
    "the perplexity score", "the memory footprint", "the storage budget",
]
ADVERBS = [
    "quickly", "hierarchically", "recursively", "sparsely", "efficiently",
    "accurately", "approximately", "iteratively", "globally", "locally",
]
CONNECTIVES = ["and", "while", "because", "so", "but", "whereas"]
OPENERS = [
    "in practice", "at scale", "during training", "after pruning",
    "under a fixed budget", "at inference time", "in each layer",
    "for large ranks", "near the diagonal", "at every level",
]


def sentence(rng: SplitMix64) -> str:
    parts = []
    if rng.uniform() < 0.3:
        parts.append(rng.choice(OPENERS) + ",")
    parts.append(rng.choice(SUBJECTS))
    parts.append(rng.choice(VERBS))
    parts.append(rng.choice(OBJECTS))
    if rng.uniform() < 0.4:
        parts.append(rng.choice(ADVERBS))
    if rng.uniform() < 0.35:
        parts.append(rng.choice(CONNECTIVES))
        parts.append(rng.choice(SUBJECTS))
        parts.append(rng.choice(VERBS))
        parts.append(rng.choice(OBJECTS))
    if rng.uniform() < 0.15:
        parts.append("with rank " + str(1 << rng.below(10)))
    text = " ".join(parts)
    return text[0].upper() + text[1:] + "."


def paragraph(rng: SplitMix64) -> str:
    n = 2 + rng.below(5)
    return " ".join(sentence(rng) for _ in range(n))


def generate(n_bytes: int, seed: int) -> str:
    rng = SplitMix64(seed)
    chunks = []
    total = 0
    while total < n_bytes:
        p = paragraph(rng)
        chunks.append(p)
        total += len(p) + 1
    return "\n".join(chunks)[:n_bytes]


SPLITS = {
    # (bytes, seed): train is enough for a few hundred steps of batch 16x128
    "train": (2_000_000, 0x5EED_0001),
    "valid": (100_000, 0x5EED_0002),
    "test": (200_000, 0x5EED_0003),
}


def write_splits(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (size, seed) in SPLITS.items():
        path = os.path.join(out_dir, f"corpus_{name}.txt")
        if os.path.exists(path) and os.path.getsize(path) == size:
            print(f"corpus: {path} up to date")
            continue
        text = generate(size, seed)
        with open(path, "w") as f:
            f.write(text)
        print(f"corpus: wrote {len(text)} bytes to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    write_splits(args.out)


if __name__ == "__main__":
    main()
