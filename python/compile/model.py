"""L2: the JAX transformer LM — dense and sHSS-compressed forward graphs.

The dense forward is the training/eval graph for the substitute model
(byte-level LM standing in for LLaMA-7B, see DESIGN.md §2). The compressed
forward swaps each q/k/v projection for the paper's sparse-plus-HSS apply,
whose hot spots run as Pallas kernels (L1):

    leaf dense blocks  -> kernels.blockdiag
    off-diag couplings -> kernels.lowrank
    COO spike matrix   -> kernels.sparse_coo
    attention          -> kernels.attention

Both graphs are lowered once by aot.py to HLO text and executed from Rust;
python never runs at serving time.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention_apply
from .kernels.blockdiag import blockdiag_apply
from .kernels.lowrank import lowrank_apply
from .kernels.sparse_coo import sparse_coo_apply

# ---------------------------------------------------------------------------
# Configuration — scaled-down stand-in for LLaMA-7B (see DESIGN.md §2).
# ---------------------------------------------------------------------------

CONFIG = {
    "vocab": 256,      # byte-level
    "d_model": 256,
    "n_heads": 8,
    "n_layers": 4,
    "d_ff": 1024,
    "seq_len": 128,
}


def param_names(cfg: Dict = CONFIG) -> List[str]:
    """Deterministic parameter order — the AOT operand order and the .hwt order."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg["n_layers"]):
        for p in ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                  "ln2_g", "ln2_b", "w1", "b1", "w2", "b2"):
            names.append(f"layer{i}.{p}")
    names += ["lnf_g", "lnf_b"]
    return names


def param_shapes(cfg: Dict = CONFIG) -> Dict[str, Tuple[int, ...]]:
    v, d, f, t = cfg["vocab"], cfg["d_model"], cfg["d_ff"], cfg["seq_len"]
    shapes: Dict[str, Tuple[int, ...]] = {"tok_emb": (v, d), "pos_emb": (t, d)}
    for i in range(cfg["n_layers"]):
        pre = f"layer{i}."
        shapes.update({
            pre + "ln1_g": (d,), pre + "ln1_b": (d,),
            pre + "wq": (d, d), pre + "wk": (d, d),
            pre + "wv": (d, d), pre + "wo": (d, d),
            pre + "ln2_g": (d,), pre + "ln2_b": (d,),
            pre + "w1": (d, f), pre + "b1": (f,),
            pre + "w2": (f, d), pre + "b2": (d,),
        })
    shapes.update({"lnf_g": (d,), "lnf_b": (d,)})
    return shapes


def init_params(seed: int = 0, cfg: Dict = CONFIG) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_shapes(cfg).items():
        base = name.split(".")[-1]
        if base.endswith("_g"):
            arr = np.ones(shape, np.float32)
        elif base.endswith("_b") or base in ("b1", "b2"):
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.standard_normal(shape).astype(np.float32) / math.sqrt(shape[0])
        params[name] = jnp.asarray(arr)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def layernorm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def gelu(x: jax.Array) -> jax.Array:
    # tanh approximation — mirrored exactly by the Rust forward pass
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _mha(q: jax.Array, k: jax.Array, v: jax.Array, n_heads: int,
         use_pallas: bool = True) -> jax.Array:
    """q,k,v: [B,T,D] -> causal attention output [B,T,D].

    use_pallas=False switches to the jnp oracle — needed on the training path
    because pallas_call has no autodiff rule; inference/AOT graphs keep the
    kernel.
    """
    bsz, t, d = q.shape
    hd = d // n_heads

    def split(x):
        return (x.reshape(bsz, t, n_heads, hd)
                 .transpose(0, 2, 1, 3)
                 .reshape(bsz * n_heads, t, hd))

    if use_pallas:
        o = attention_apply(split(q), split(k), split(v))
    else:
        from .kernels.ref import attention_ref
        o = jax.vmap(attention_ref)(split(q), split(k), split(v))
    return (o.reshape(bsz, n_heads, t, hd)
             .transpose(0, 2, 1, 3)
             .reshape(bsz, t, d))


# --- sHSS apply at trace time ---------------------------------------------
#
# The tree arrives as (static spec, flat operand dict); see hss_np.flatten.
# Operands represent A = W^T so that rows(X) @ W == (A @ X^T)^T; hss_apply
# works on column-major batches [n, B].

def hss_apply(spec: Dict, ops: Dict[str, jax.Array], prefix: str,
              x: jax.Array) -> jax.Array:
    if spec["leaf"]:
        d = ops[prefix + ".leaf"]
        return blockdiag_apply(d[None], x[None])[0]
    n = spec["n"]
    n0 = n // 2
    if spec.get("nnz", 0) > 0:
        ys = sparse_coo_apply(ops[prefix + ".rows"], ops[prefix + ".cols"],
                              ops[prefix + ".vals"], x, n)
    else:
        ys = jnp.zeros_like(x)
    perm = ops[prefix + ".perm"]
    xp = x[perm, :]
    x0, x1 = xp[:n0], xp[n0:]
    y0 = hss_apply(spec["c0"], ops, prefix + ".c0", x0) + lowrank_apply(
        ops[prefix + ".u0"], ops[prefix + ".r0"], x1)
    y1 = hss_apply(spec["c1"], ops, prefix + ".c1", x1) + lowrank_apply(
        ops[prefix + ".u1"], ops[prefix + ".r1"], x0)
    yh = jnp.concatenate([y0, y1], axis=0)
    y = jnp.zeros_like(yh).at[perm, :].set(yh)
    return ys + y


def hss_project(spec: Dict, ops: Dict[str, jax.Array], prefix: str,
                a: jax.Array) -> jax.Array:
    """rows(a) @ W for a: [B,T,D], where ops encode A = W^T."""
    bsz, t, d = a.shape
    x = a.reshape(bsz * t, d).T          # [D, B*T] column batch
    y = hss_apply(spec, ops, prefix, x)  # [D, B*T]
    return y.T.reshape(bsz, t, d)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def fwd(params: Dict[str, jax.Array], tokens: jax.Array,
        cfg: Dict = CONFIG, hss=None, use_pallas: bool = True) -> jax.Array:
    """Logits [B,T,V]. If `hss=(specs, ops)` is given, q/k/v run compressed.

    specs[f"layer{i}.w{q,k,v}"] is the static tree spec from hss_np.spec and
    ops holds all flat operand arrays (names prefixed the same way).
    """
    bsz, t = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    for i in range(cfg["n_layers"]):
        pre = f"layer{i}."
        a = layernorm(h, params[pre + "ln1_g"], params[pre + "ln1_b"])
        if hss is None:
            q = a @ params[pre + "wq"]
            k = a @ params[pre + "wk"]
            v = a @ params[pre + "wv"]
        else:
            specs, ops = hss
            q = hss_project(specs[pre + "wq"], ops, pre + "wq", a)
            k = hss_project(specs[pre + "wk"], ops, pre + "wk", a)
            v = hss_project(specs[pre + "wv"], ops, pre + "wv", a)
        o = _mha(q, k, v, cfg["n_heads"], use_pallas=use_pallas)
        h = h + o @ params[pre + "wo"]
        m = layernorm(h, params[pre + "ln2_g"], params[pre + "ln2_b"])
        h = h + gelu(m @ params[pre + "w1"] + params[pre + "b1"]) @ params[pre + "w2"] \
            + params[pre + "b2"]
    hf = layernorm(h, params["lnf_g"], params["lnf_b"])
    return hf @ params["tok_emb"].T


def loss_fn(params: Dict[str, jax.Array], tokens: jax.Array,
            cfg: Dict = CONFIG) -> jax.Array:
    """Next-token cross-entropy (mean nats/token) over tokens [B, T+1]."""
    logits = fwd(params, tokens[:, :-1], cfg, use_pallas=False)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
