//! End-to-end coverage of the sharded `HSB2` store: both on-disk forms
//! loading identically through the one `ModelStore`/`VariantFile` API,
//! newer-save-seq resolution between them, per-shard corruption isolation
//! at the model level, atomic pruning of sharded variants, and the
//! zero-copy aliasing guarantee — an mmap-backed model's `apply_batch` is
//! bitwise identical to a buffered load's.

use hisolo::compress::Method;
use hisolo::compress::CompressorConfig;
use hisolo::linalg::Matrix;
use hisolo::model::{CompressedModel, ModelConfig, Transformer};
use hisolo::store::{MmapMode, ModelStore};
use hisolo::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hisolo_sharded_store_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_base() -> Arc<Transformer> {
    Arc::new(Transformer::random(
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 3,
            d_ff: 64,
            seq_len: 16,
        },
        21,
    ))
}

fn cfg() -> CompressorConfig {
    CompressorConfig {
        rank: 8,
        sparsity: 0.15,
        depth: 2,
        min_leaf: 8,
        ..Default::default()
    }
}

/// The monolithic `HSB1` and sharded `HSB2` forms of the same model load
/// identically through the same API: same reports, same forward logits
/// to the bit. (The formats differ only in layout and alignment pads —
/// never in the value bytes.)
#[test]
fn both_forms_load_identically_through_same_api() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("both_forms"));
    let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, cfg());
    store.save_model("mono", &cm).unwrap();
    store.save_model_sharded("sharded", &cm).unwrap();
    assert_eq!(
        store.variants(),
        vec!["mono".to_string(), "sharded".to_string()]
    );

    let mono = store.open_variant("mono").unwrap();
    let sharded = store.open_variant("sharded").unwrap();
    assert!(!mono.is_sharded());
    assert!(sharded.is_sharded());
    assert_eq!(sharded.shard_count(), 3, "one shard per layer");
    assert_eq!(mono.names(), sharded.names());

    let m_model = CompressedModel::from_store(base.clone(), &mono).unwrap();
    let s_model = CompressedModel::from_store(base.clone(), &sharded).unwrap();
    for (a, b) in m_model.reports.iter().zip(&s_model.reports) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.params, b.params);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits(), "{}", a.name);
    }
    let tokens: Vec<u32> = (0..16).map(|i| (i * 7) % 64).collect();
    let ya = m_model.forward(&tokens);
    let yb = s_model.forward(&tokens);
    for (i, (a, b)) in ya.data.iter().zip(yb.data.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logit {i}");
    }
}

/// When one variant name exists in both forms, `open_variant` resolves
/// to the newer save-seq (tie → sharded), and `variant_save_seq` reports
/// the winning sequence.
#[test]
fn open_variant_prefers_newer_form() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("prefer_newer"));
    let cm = CompressedModel::compress(base.clone(), Method::SSvd, cfg());

    store.save_model("v", &cm).unwrap(); // seq 1, monolithic
    store.save_model_sharded("v", &cm).unwrap(); // seq 2, sharded
    assert_eq!(store.variant_save_seq("v"), Some(2));
    let f = store.open_variant("v").unwrap();
    assert!(f.is_sharded(), "sharded form is newer");
    assert_eq!(f.save_seq(), 2);

    store.save_model("v", &cm).unwrap(); // seq 3, monolithic again
    assert_eq!(store.variant_save_seq("v"), Some(3));
    let f = store.open_variant("v").unwrap();
    assert!(!f.is_sharded(), "monolithic form is newer now");
    assert_eq!(f.save_seq(), 3);
}

/// A bit flip inside one layer's shard fails that layer's load — with an
/// error naming the shard file — while every other layer still decodes.
#[test]
fn shard_corruption_isolated_and_named() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("isolation"));
    let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, cfg());
    let dir = store.save_model_sharded("v", &cm).unwrap();

    let shard = dir.join("layer1.shard");
    let mut bytes = std::fs::read(&shard).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&shard, &bytes).unwrap();

    let f = store.open_variant("v").unwrap(); // manifest + lengths intact
    assert!(f.load_native("layer0.wq").is_ok());
    assert!(f.load_native("layer2.wv").is_ok());
    let err = format!("{:#}", f.load_native("layer1.wk").unwrap_err());
    assert!(err.contains("layer1.shard"), "{err}");

    // the whole-model load fails for the same reason, same name
    let err = format!(
        "{:#}",
        CompressedModel::from_store(base.clone(), &f).unwrap_err()
    );
    assert!(err.contains("layer1.shard"), "{err}");

    // a missing shard is rejected at open, naming it
    std::fs::remove_file(&shard).unwrap();
    let err = format!("{:#}", store.open_variant("v").unwrap_err());
    assert!(err.contains("layer1.shard") && err.contains("missing"), "{err}");
}

/// `prune` deletes a sharded variant atomically — directory fully gone,
/// manifest removed first (no window where a manifest references missing
/// shards) — and never touches the active variant.
#[test]
fn prune_deletes_sharded_variants() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("prune"));
    let cm = CompressedModel::compress(base.clone(), Method::SSvd, cfg());
    for name in ["s0", "s1", "s2"] {
        store.save_model_sharded(name, &cm).unwrap();
    }
    store.save_model("m0", &cm).unwrap(); // seq 4, newest

    // keep 2 newest (m0, s2); s0 is active and immune
    let deleted = store.prune(2, Some("s0")).unwrap();
    assert_eq!(deleted, vec!["s1".to_string()]);
    assert!(!store.sharded_path("s1").exists(), "directory fully removed");
    assert_eq!(
        store.variants(),
        vec!["m0".to_string(), "s0".to_string(), "s2".to_string()]
    );
    // survivors still open and load
    assert!(store.open_variant("s0").is_ok());
    assert!(store.load_model("s2", base.clone()).is_ok());

    // a manifest-less shard directory (mid-delete crash image) is not a
    // variant: it can't be opened, and a fresh prune reclaims nothing new
    let dir = store.sharded_path("s2");
    std::fs::remove_file(dir.join("manifest.hsb2")).unwrap();
    assert!(store.open_variant("s2").is_err());
}

/// The aliasing acceptance check: an mmap-backed model (weight buffers
/// borrowing the mapping) runs `apply_batch` bitwise identical to a
/// fully-buffered load of the same variant, entry by entry.
#[test]
fn mmap_apply_batch_bitwise_identical_to_buffered() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("aliasing"));
    let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, cfg());
    store.save_model_sharded("v", &cm).unwrap();

    let mapped = store.open_variant_with("v", MmapMode::Auto).unwrap();
    let buffered = store.open_variant_with("v", MmapMode::Buffered).unwrap();
    assert!(!buffered.is_mapped());
    if cfg!(unix) && std::env::var("HISOLO_MMAP").is_err() {
        assert!(mapped.is_mapped(), "Auto must map on unix");
    }

    let n = base.cfg.d_model;
    let k = 5;
    let mut rng = Rng::new(3);
    let x = Matrix::from_vec(n, k, (0..n * k).map(|_| rng.gaussian_f32()).collect());
    for name in buffered.names() {
        let a = mapped.load_native(name).unwrap();
        let b = buffered.load_native(name).unwrap();
        let mut ya = Matrix::zeros(n, k);
        let mut yb = Matrix::zeros(n, k);
        a.apply_batch(&x, &mut ya, &mut a.workspace_for(k));
        b.apply_batch(&x, &mut yb, &mut b.workspace_for(k));
        for (i, (va, vb)) in ya.data.iter().zip(yb.data.iter()).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{name}[{i}]");
        }
    }
}
