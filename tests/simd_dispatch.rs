//! SIMD dispatch integration test — deliberately its own binary so the
//! `HISOLO_SIMD=off` override is installed **before** anything in the
//! process touches the dispatch table (detection is once-per-process; a
//! unit test inside the lib crate races other tests for first touch).
//!
//! Covers: the env kill-switch pins the scalar arm, `force_level`
//! round-trips and ignores unsupported levels, and — the serving
//! acceptance criterion — a batched end-to-end forward produces
//! bit-identical logits (hence bit-identical NLL) under the scalar arm
//! and under every accelerated arm the host supports.

use hisolo::linalg::simd::{self, SimdLevel};
use hisolo::model::{ModelConfig, Transformer};

/// Mean NLL of each window's next-token predictions from raw logits
/// (f32 log-sum-exp, deterministic order — bitwise comparable).
fn nll(logits: &[hisolo::linalg::Matrix], windows: &[&[u32]]) -> f32 {
    let mut total = 0.0f32;
    let mut count = 0usize;
    for (lg, w) in logits.iter().zip(windows) {
        for i in 0..w.len() - 1 {
            let row = lg.row(i);
            let mut m = f32::NEG_INFINITY;
            for &v in row {
                if v > m {
                    m = v;
                }
            }
            let mut z = 0.0f32;
            for &v in row {
                z += (v - m).exp();
            }
            total += z.ln() + m - row[w[i + 1] as usize];
            count += 1;
        }
    }
    total / count as f32
}

#[test]
fn env_off_pins_scalar_and_accelerated_forward_is_bit_identical() {
    // must precede the first active_level()/kernels() call in this process
    std::env::set_var("HISOLO_SIMD", "off");
    assert_eq!(
        simd::active_level(),
        SimdLevel::Scalar,
        "HISOLO_SIMD=off must pin the scalar arm"
    );

    // force_level returns the previous level and ignores levels this CPU
    // cannot run (Scalar itself is always supported)
    let prev = simd::force_level(SimdLevel::Scalar);
    assert_eq!(prev, SimdLevel::Scalar);
    assert_eq!(simd::active_level(), SimdLevel::Scalar);

    let cfg = ModelConfig {
        vocab: 64,
        d_model: 32,
        n_heads: 4,
        n_layers: 2,
        d_ff: 64,
        seq_len: 16,
    };
    let m = Transformer::random(cfg, 17);
    let w1: Vec<u32> = (0..16).map(|i| (i * 5) % 64).collect();
    let w2: Vec<u32> = (0..9).map(|i| (i * 13 + 4) % 64).collect();
    let windows: [&[u32]; 2] = [&w1, &w2];

    let scalar_logits = m.forward_batch(&windows);
    let scalar_nll = nll(&scalar_logits, &windows);
    assert!(scalar_nll.is_finite());

    // every accelerated level the host supports must reproduce the scalar
    // pass bit-for-bit (the module's 0-ULP contract, measured end to end)
    for lvl in [SimdLevel::Avx2, SimdLevel::Neon] {
        let before = simd::force_level(lvl);
        assert_eq!(before, SimdLevel::Scalar, "restore bookkeeping");
        if simd::active_level() != lvl {
            // unsupported on this host: the force must have been ignored
            assert_eq!(simd::active_level(), SimdLevel::Scalar);
            continue;
        }
        let fast_logits = m.forward_batch(&windows);
        for (a, b) in scalar_logits.iter().zip(&fast_logits) {
            assert_eq!(
                a.data.as_f32(),
                b.data.as_f32(),
                "{} logits differ from scalar",
                lvl.name()
            );
        }
        let fast_nll = nll(&fast_logits, &windows);
        assert_eq!(
            scalar_nll.to_bits(),
            fast_nll.to_bits(),
            "{} NLL differs from scalar",
            lvl.name()
        );
        simd::force_level(SimdLevel::Scalar);
    }

    // leave the process where the env asked it to be
    simd::force_level(SimdLevel::Scalar);
    assert_eq!(simd::active_level(), SimdLevel::Scalar);
}
