//! End-to-end tests of the `train/` subsystem: calibration must beat the
//! one-shot baseline for every compressed variant, and a refined model
//! must round-trip through the `HSB1` store into a live
//! `Coordinator::swap_variant` under simulated traffic.

use hisolo::compress::{CompressorConfig, Method};
use hisolo::coordinator::worker::NativeCompressedScorer;
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::dataset::windows;
use hisolo::model::{CompressedModel, ModelConfig, Transformer};
use hisolo::store::ModelStore;
use hisolo::train::{calibrate_model, TrainConfig};
use std::sync::Arc;
use std::time::Duration;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 32,
        n_heads: 4,
        n_layers: 2,
        d_ff: 64,
        seq_len: 16,
    }
}

fn tiny_base(seed: u64) -> Arc<Transformer> {
    Arc::new(Transformer::random(tiny_cfg(), seed))
}

fn calib_windows(count: usize) -> Vec<Vec<u32>> {
    windows(&hisolo::data::synthetic::token_stream(2_000, 64), 16, count)
}

fn compressor_cfg() -> CompressorConfig {
    CompressorConfig {
        rank: 4,
        sparsity: 0.08,
        depth: 2,
        min_leaf: 4,
        ..Default::default()
    }
}

fn train_cfg() -> TrainConfig {
    TrainConfig {
        steps: 200,
        ..Default::default()
    }
}

/// The acceptance bar: 200 calibration steps reduce the relative
/// Frobenius reconstruction error vs the one-shot baseline for all three
/// sparse-plus-X variants the paper deploys — sSVD and sR-SVD (LowRank
/// factors + frozen-pattern CSR values) and sHSS-RCM (the recursive HSS
/// tree). Plain SVD is excluded on purpose: its one-shot truncation is
/// already the Frobenius-optimal rank-k matrix (Eckart–Young), so no
/// training objective can improve that metric; the greedy sparse-plus-X
/// one-shots are jointly suboptimal, which is exactly the gap layer-wise
/// calibration recovers.
#[test]
fn calibration_beats_oneshot_for_all_variants() {
    let base = tiny_base(1);
    let ws = calib_windows(8);
    for method in [Method::SSvd, Method::SRsvd, Method::SHssRcm] {
        let mut cm = CompressedModel::compress(base.clone(), method, compressor_cfg());
        let before = cm.mean_rel_error();
        let reports = calibrate_model(&mut cm, &ws, &train_cfg());
        let after = cm.mean_rel_error();
        assert_eq!(reports.len(), 6, "{method:?}");
        assert!(reports.iter().all(|r| r.steps_run > 0), "{method:?}");
        assert!(
            after < before,
            "{method:?}: mean rel error {before} -> {after} (no improvement)"
        );
        // every individual projection improved, not just the mean
        for r in &reports {
            assert!(
                r.rel_err_after < r.rel_err_before,
                "{method:?} {}: {} -> {}",
                r.name,
                r.rel_err_before,
                r.rel_err_after
            );
        }
    }
}

/// finetune → ModelStore save → Coordinator::swap_variant: the refined
/// variant must survive the fp16 store round trip and serve under
/// simulated traffic, landing closer to the dense teacher than the
/// one-shot model it replaced.
#[test]
fn refined_variant_roundtrips_through_store_and_hotswap() {
    let base = tiny_base(2);
    let ws = calib_windows(8);
    let oneshot = Arc::new(CompressedModel::compress(
        base.clone(),
        Method::SHssRcm,
        compressor_cfg(),
    ));

    // refine a second copy offline and persist it as a new variant
    let mut refined = CompressedModel::compress(base.clone(), Method::SHssRcm, compressor_cfg());
    calibrate_model(&mut refined, &ws, &train_cfg());
    let dir = std::env::temp_dir().join("hisolo_test_train_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::open(&dir);
    store.save_model("shss-rcm-ft", &refined).unwrap();

    // serve the one-shot model ...
    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            capacity: 256,
            ..BatcherConfig::default()
        },
    });
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model: oneshot.clone(),
            max_batch: 4,
            kv: None,
        },
    );
    let before = coord.submit_all(Variant::Hss, &ws).unwrap();
    assert!(before.iter().all(|r| r.error.is_none()));

    // ... hot-swap to the refined variant straight from the store
    let swap_base = base.clone();
    let swap_dir = dir.clone();
    let ticket = coord
        .swap_variant(Variant::Hss, move || {
            let store = ModelStore::open(&swap_dir);
            let model = Arc::new(store.load_model("shss-rcm-ft", swap_base.clone())?);
            Ok(NativeCompressedScorer {
                model,
                max_batch: 4,
                kv: None,
            })
        })
        .unwrap();
    ticket.wait(Duration::from_secs(30)).unwrap();

    let after = coord.submit_all(Variant::Hss, &ws).unwrap();
    assert!(after.iter().all(|r| r.error.is_none()));

    // the served refined scores match the refined model evaluated locally
    // through the same store round trip (fp16 quantization included)
    let loaded = store.load_model("shss-rcm-ft", base.clone()).unwrap();
    for (resp, w) in after.iter().zip(&ws) {
        let logits = loaded.forward(&w[..w.len() - 1]);
        let (nll, _) = hisolo::eval::perplexity::window_nll(&logits, w);
        assert!(
            (resp.nll - nll).abs() < 1e-6 * nll.abs().max(1.0),
            "served nll {} vs local {}",
            resp.nll,
            nll
        );
    }

    // and refinement really moved the served model toward the teacher:
    // mean |logits − dense logits| shrinks vs the one-shot variant
    let mut d_oneshot = 0.0f64;
    let mut d_refined = 0.0f64;
    let mut count = 0usize;
    for w in &ws {
        let toks = &w[..w.len() - 1];
        let dense = base.forward(toks);
        let a = oneshot.forward(toks);
        let b = loaded.forward(toks);
        for i in 0..dense.data.len() {
            d_oneshot += (a.data[i] - dense.data[i]).abs() as f64;
            d_refined += (b.data[i] - dense.data[i]).abs() as f64;
            count += 1;
        }
    }
    d_oneshot /= count as f64;
    d_refined /= count as f64;
    assert!(
        d_refined < d_oneshot,
        "refined logit gap {d_refined} !< one-shot {d_oneshot}"
    );

    coord.shutdown();
}

/// Store retention composes with the refine → save flow: old one-shot
/// variants are pruned while the actively-served refined variant stays.
#[test]
fn prune_after_refinement_keeps_served_variant() {
    let base = tiny_base(3);
    let dir = std::env::temp_dir().join("hisolo_test_train_prune");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::open(&dir);
    for (i, name) in ["oneshot-a", "oneshot-b", "refined"].iter().enumerate() {
        let cm = CompressedModel::compress(base.clone(), Method::SSvd, CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            seed: 100 + i as u64,
            ..Default::default()
        });
        store.save_model(name, &cm).unwrap();
        std::thread::sleep(Duration::from_millis(15));
    }
    // keep-0 with an active variant: everything but the served one goes
    let deleted = store.prune(0, Some("refined")).unwrap();
    assert_eq!(deleted, vec!["oneshot-a".to_string(), "oneshot-b".to_string()]);
    assert_eq!(store.variants(), vec!["refined".to_string()]);
    assert!(store.load_model("refined", base).is_ok());
}
