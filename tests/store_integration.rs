//! End-to-end `store/` coverage: HSB1 round-trip equivalence for every
//! `CompressedMatrix` variant, corruption rejection, and the coordinator
//! serving correct responses before, during, and after a live hot-swap
//! whose replacement model is cold-loaded from the store.

use hisolo::compress::{CompressedMatrix, Compressor, CompressorConfig, Method};
use hisolo::coordinator::worker::NativeCompressedScorer;
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::dataset::windows;
use hisolo::model::{CompressedModel, ModelConfig, Transformer};
use hisolo::store::{ModelStore, StoreFile, StoreWriter};
use hisolo::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hisolo_store_integration_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spiky(n: usize, seed: u64) -> hisolo::linalg::Matrix {
    let mut rng = Rng::new(seed);
    let mut a = hisolo::linalg::Matrix::randn(n, n, seed).scale(0.05);
    for _ in 0..3 * n {
        let i = rng.below(n);
        let j = rng.below(n);
        a.data[i * n + j] += rng.gaussian_f32();
    }
    a
}

/// Acceptance invariant: for each of Dense / LowRank / Hss,
/// `save(m); let m2 = load();` gives identical `storage_ratio()` and
/// matvec outputs within fp16 tolerance.
#[test]
fn save_load_matvec_equivalence_all_variants() {
    let n = 64;
    let w = spiky(n, 42);
    let comp = Compressor::new(CompressorConfig {
        rank: 8,
        sparsity: 0.15,
        depth: 2,
        min_leaf: 8,
        ..Default::default()
    });
    let dir = temp_dir("equivalence");
    for (method, kind) in [
        (Method::Dense, "dense"),
        (Method::SSvd, "lowrank"),
        (Method::SHssRcm, "hss"),
    ] {
        let m = comp.compress(&w, method);
        let path = dir.join(format!("{kind}.hsb1"));
        let mut sw = StoreWriter::new();
        sw.push_with_meta("w", &m, Some(method), m.rel_error(&w));
        sw.finish(&path).unwrap();

        let file = StoreFile::open(&path).unwrap();
        let (m2, mut ws) = file.load_with_workspace("w").unwrap();

        // storage accounting identical (shapes and nnz survive exactly)
        assert_eq!(m2.storage_ratio(), m.storage_ratio(), "{kind}");
        assert_eq!(m2.params(), m.params(), "{kind}");
        assert_eq!(m2.bytes(), m.bytes(), "{kind}");
        matches_kind(&m2, kind);

        // matvec within fp16 tolerance of the pre-save matrix
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let expect = m.matvec(&x);
        let mut got = vec![0.0f32; n];
        m2.matvec_with(&x, &mut got, &mut ws);
        let scale: f32 = expect.iter().map(|v| v.abs()).fold(0.0, f32::max).max(1.0);
        for (i, (a, b)) in got.iter().zip(&expect).enumerate() {
            assert!(
                (a - b).abs() <= 2e-2 * scale,
                "{kind}[{i}]: {a} vs {b} (scale {scale})"
            );
        }
    }
}

fn matches_kind(m: &CompressedMatrix, kind: &str) {
    let got = match m {
        CompressedMatrix::Dense { .. } => "dense",
        CompressedMatrix::LowRank { .. } => "lowrank",
        CompressedMatrix::Hss { .. } => "hss",
    };
    assert_eq!(got, kind);
}

#[test]
fn truncated_and_corrupted_stores_rejected() {
    let dir = temp_dir("corruption");
    let m = Compressor::new(CompressorConfig {
        rank: 4,
        sparsity: 0.1,
        depth: 1,
        min_leaf: 8,
        ..Default::default()
    })
    .compress(&spiky(32, 1), Method::SHssRcm);
    let mut sw = StoreWriter::new();
    sw.push("w", &m);
    let path = dir.join("good.hsb1");
    sw.finish(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncations at every stride fail closed
    for cut in (0..bytes.len()).step_by(bytes.len() / 17 + 1) {
        let p = dir.join("truncated.hsb1");
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(StoreFile::open(&p).is_err(), "cut={cut}");
    }
    // single-byte corruption anywhere is caught by the crc footer
    for pos in (0..bytes.len()).step_by(bytes.len() / 13 + 1) {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x80;
        let p = dir.join("corrupt.hsb1");
        std::fs::write(&p, &bad).unwrap();
        assert!(StoreFile::open(&p).is_err(), "pos={pos}");
    }
    // the pristine file still loads
    assert!(StoreFile::open(&path).is_ok());
}

fn tiny_base() -> Arc<Transformer> {
    Arc::new(Transformer::random(
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        },
        9,
    ))
}

/// Near-lossless config so dense and both stored variants agree on NLL:
/// rank 32 is full rank for the d=32 sSVD factors and caps to the full
/// off-diagonal rank (16) inside the depth-1 HSS tree.
fn lossless_cfg() -> CompressorConfig {
    CompressorConfig {
        rank: 32,
        sparsity: 0.2,
        depth: 1,
        hss_rsvd: false,
        min_leaf: 4,
        ..Default::default()
    }
}

/// Acceptance invariant: `Coordinator::swap_variant` serves correct
/// responses before, during, and after a hot-swap from the store.
#[test]
fn coordinator_serves_correctly_across_store_hot_swap() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("hotswap"));

    // persist two near-lossless variants, then drop the in-RAM models:
    // everything the coordinator serves after this line comes from disk
    for (name, method) in [("ssvd", Method::SSvd), ("shss-rcm", Method::SHssRcm)] {
        let cm = CompressedModel::compress(base.clone(), method, lossless_cfg());
        store.save_model(name, &cm).unwrap();
    }

    let toks: Vec<u32> = (0..4000u32).map(|i| (i * 31 + i / 5) % 64).collect();
    let ws = windows(&toks, base.cfg.seq_len, 30);
    let dense_nll: Vec<f64> = ws
        .iter()
        .map(|w| hisolo::eval::perplexity::window_nll(&base.forward(&w[..16]), w).0)
        .collect();

    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            capacity: 256,
            ..BatcherConfig::default()
        },
    });
    // cold start the lane from the store
    let first = Arc::new(store.load_model("ssvd", base.clone()).unwrap());
    assert_eq!(first.method, Method::SSvd);
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model: first,
            max_batch: 4,
            kv: None,
        },
    );

    let check = |resps: &[hisolo::coordinator::ScoreResponse], phase: &str| {
        for (r, want) in resps.iter().zip(&dense_nll) {
            assert!(r.error.is_none(), "{phase}: {:?}", r.error);
            let rel = (r.nll - want).abs() / want.abs().max(1e-9);
            assert!(rel < 0.05, "{phase}: nll {} vs dense {want} (rel {rel})", r.nll);
        }
    };

    // BEFORE the swap
    let before = coord.submit_all(Variant::Hss, &ws).unwrap();
    check(&before, "before");

    // DURING: fire the swap while a wave of requests is in flight; every
    // response must be correct no matter which scorer answered it
    let rxs: Vec<_> = ws
        .iter()
        .map(|w| coord.submit(Variant::Hss, w.clone()).unwrap())
        .collect();
    let swap_base = base.clone();
    let swap_store = ModelStore::open(store.dir().to_path_buf());
    let ticket = coord
        .swap_variant(Variant::Hss, move || {
            let model = Arc::new(swap_store.load_model("shss-rcm", swap_base.clone())?);
            Ok(NativeCompressedScorer {
                model,
                max_batch: 4,
                kv: None,
            })
        })
        .unwrap();
    let during: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
        .collect();
    check(&during, "during");
    ticket.wait(Duration::from_secs(10)).unwrap();
    assert_eq!(
        coord
            .metrics
            .swaps
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );

    // AFTER: the sHSS-RCM variant now serves, still correct
    let after = coord.submit_all(Variant::Hss, &ws).unwrap();
    check(&after, "after");
    coord.shutdown();
}

/// Acceptance: f16-resident serving end-to-end. A store-loaded model
/// keeps its fp16 factors resident at exactly half the widened bytes, the
/// coordinator's per-variant gauge reports the halving when the
/// prefetched hot-swap installs it, and every served NLL matches the
/// f32-resident serving of the same variant — the widened kernels change
/// residency, not arithmetic.
#[test]
fn f16_resident_model_serves_end_to_end_at_half_the_bytes() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("f16_serve"));
    let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, lossless_cfg());
    store.save_model("shss-rcm", &cm).unwrap();

    // the native load keeps the on-disk dtype; widening doubles residency
    let f16_model = Arc::new(store.load_model("shss-rcm", base.clone()).unwrap());
    assert_eq!(f16_model.weights_dtype(), hisolo::linalg::Dtype::F16);
    let mut f32_model = store.load_model("shss-rcm", base.clone()).unwrap();
    f32_model.widen_to_f32();
    let (half, full) = (
        f16_model.resident_weight_bytes(),
        f32_model.resident_weight_bytes(),
    );
    assert_eq!(half * 2, full, "f16 residency must be exactly half");
    let f32_model = Arc::new(f32_model);

    let toks: Vec<u32> = (0..3000u32).map(|i| (i * 17 + i / 3) % 64).collect();
    let ws = windows(&toks, base.cfg.seq_len, 20);

    // start the lane on the f32-resident model…
    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            capacity: 256,
            ..BatcherConfig::default()
        },
    });
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model: f32_model,
            max_batch: 4,
            kv: None,
        },
    );
    let before = coord.submit_all(Variant::Hss, &ws).unwrap();
    assert!(before.iter().all(|r| r.error.is_none()));
    assert_eq!(
        coord.metrics.resident_weight_bytes(Variant::Hss),
        full as u64
    );

    // …then hot-swap to the f16-resident scorer with background prefetch
    // (the store parse happens on a helper thread, not the serving lane)
    let swap_model = f16_model.clone();
    let ticket = coord
        .swap_variant_prefetched(Variant::Hss, move || {
            Ok(NativeCompressedScorer {
                model: swap_model.clone(),
                max_batch: 4,
                kv: None,
            })
        })
        .unwrap();
    ticket.wait(Duration::from_secs(30)).unwrap();
    assert_eq!(
        coord.metrics.resident_weight_bytes(Variant::Hss),
        half as u64,
        "gauge must show the f16 halving after the swap"
    );

    // perplexity parity: the f16-resident server computes the same NLLs
    let after = coord.submit_all(Variant::Hss, &ws).unwrap();
    for (a, b) in after.iter().zip(&before) {
        assert!(a.error.is_none(), "{:?}", a.error);
        assert!(
            (a.nll - b.nll).abs() <= 1e-9 * b.nll.abs().max(1.0),
            "f16 nll {} vs f32 nll {}",
            a.nll,
            b.nll
        );
    }
    coord.shutdown();
}

/// A swap whose factory fails (missing variant) must leave the old model
/// serving — a bad rollout can't take the lane down.
#[test]
fn failed_store_swap_keeps_lane_healthy() {
    let base = tiny_base();
    let store = ModelStore::open(temp_dir("badswap"));
    let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, lossless_cfg());
    store.save_model("good", &cm).unwrap();

    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let model = Arc::new(store.load_model("good", base.clone()).unwrap());
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model,
            max_batch: 4,
            kv: None,
        },
    );

    let swap_store = ModelStore::open(store.dir().to_path_buf());
    let swap_base = base.clone();
    let ticket = coord
        .swap_variant(Variant::Hss, move || {
            let model = Arc::new(swap_store.load_model("absent", swap_base.clone())?);
            Ok(NativeCompressedScorer {
                model,
                max_batch: 4,
                kv: None,
            })
        })
        .unwrap();
    assert!(ticket.wait(Duration::from_secs(10)).is_err());

    let toks: Vec<u32> = (0..500u32).map(|i| i % 64).collect();
    let ws = windows(&toks, base.cfg.seq_len, 4);
    let resps = coord.submit_all(Variant::Hss, &ws).unwrap();
    assert!(resps.iter().all(|r| r.error.is_none()));
    coord.shutdown();
}
