//! Integration: AOT HLO executables (L1 Pallas + L2 JAX, compiled by PJRT)
//! vs the native Rust forward pass, on the real artifacts.
//!
//! These tests skip (pass trivially) when `artifacts/` has not been built —
//! run `make artifacts` first for full coverage.

use hisolo::data::corpus::Corpus;
use hisolo::data::dataset::windows;
use hisolo::eval::perplexity::window_nll;
use hisolo::model::{ModelConfig, Transformer, WeightFile};
use hisolo::runtime::{ArtifactDir, Runtime};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn dense_hlo_matches_native_forward() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let artifacts = ArtifactDir::load(&dir).unwrap();
    let weights = WeightFile::load(&dir.join("model.hwt")).unwrap();
    let cfg = artifacts.model_config;
    assert_eq!(cfg, ModelConfig::default());

    let rt = Runtime::cpu().unwrap();
    let model = rt
        .load_model(&artifacts, "model_dense_b1", &[&weights])
        .unwrap();

    let native = Transformer::from_weights(&weights, cfg).unwrap();
    let corpus = Corpus::load(&dir.join("corpus_test.txt")).unwrap();
    let w = windows(&corpus.tokens, cfg.seq_len, 1).remove(0);
    let input = w[..cfg.seq_len].to_vec();

    let hlo_logits = model.score(&[input.clone()]).unwrap().remove(0);
    let native_logits = native.forward(&input);

    assert_eq!(hlo_logits.rows, native_logits.rows);
    assert_eq!(hlo_logits.cols, native_logits.cols);
    let mut max_diff = 0.0f32;
    for (a, b) in hlo_logits.data.iter().zip(&native_logits.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    // two independent implementations (XLA fused f32 vs scalar Rust); logits
    // are O(10), so 3e-2 absolute is tight agreement
    assert!(max_diff < 3e-2, "max logit diff {max_diff}");
}

#[test]
fn hss_hlo_close_to_dense_on_real_weights() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let artifacts = ArtifactDir::load(&dir).unwrap();
    let weights = WeightFile::load(&dir.join("model.hwt")).unwrap();
    let hss_ops = WeightFile::load(&dir.join("hss_operands.hwt")).unwrap();
    let cfg = artifacts.model_config;

    let rt = Runtime::cpu().unwrap();
    let dense = rt
        .load_model(&artifacts, "model_dense_b1", &[&weights])
        .unwrap();
    let hss = rt
        .load_model(&artifacts, "model_hss_b1", &[&weights, &hss_ops])
        .unwrap();

    let corpus = Corpus::load(&dir.join("corpus_test.txt")).unwrap();
    let ws = windows(&corpus.tokens, cfg.seq_len, 4);

    // compressed model must stay close in NLL (sp30/rank32 config, the
    // paper's headline operating point)
    let mut nll_dense = 0.0;
    let mut nll_hss = 0.0;
    let mut toks = 0usize;
    for w in &ws {
        let input = w[..cfg.seq_len].to_vec();
        let ld = dense.score(&[input.clone()]).unwrap().remove(0);
        let lh = hss.score(&[input]).unwrap().remove(0);
        let (nd, t) = window_nll(&ld, w);
        let (nh, _) = window_nll(&lh, w);
        nll_dense += nd;
        nll_hss += nh;
        toks += t;
    }
    let ppl_dense = (nll_dense / toks as f64).exp();
    let ppl_hss = (nll_hss / toks as f64).exp();
    eprintln!("ppl dense={ppl_dense:.4} hss={ppl_hss:.4}");
    assert!(ppl_dense > 1.0 && ppl_dense < 3.0, "dense ppl {ppl_dense}");
    // compressed must stay far below the uniform bound (256) and within
    // 50% relative of dense — the small substitute model amplifies
    // compression noise vs the paper's 7B; method *ordering* is asserted
    // by the fig2/fig3 benches instead.
    assert!(
        ppl_hss < ppl_dense * 1.5,
        "hss ppl {ppl_hss} vs dense {ppl_dense}"
    );
}

#[test]
fn batched_executable_matches_b1() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let artifacts = ArtifactDir::load(&dir).unwrap();
    let weights = WeightFile::load(&dir.join("model.hwt")).unwrap();
    let cfg = artifacts.model_config;

    let rt = Runtime::cpu().unwrap();
    let b1 = rt
        .load_model(&artifacts, "model_dense_b1", &[&weights])
        .unwrap();
    let b8 = rt
        .load_model(&artifacts, "model_dense_b8", &[&weights])
        .unwrap();

    let corpus = Corpus::load(&dir.join("corpus_valid.txt")).unwrap();
    let ws = windows(&corpus.tokens, cfg.seq_len, 3);
    let inputs: Vec<Vec<u32>> = ws.iter().map(|w| w[..cfg.seq_len].to_vec()).collect();

    // partial batch (3 of 8) exercises padding
    let batched = b8.score(&inputs).unwrap();
    assert_eq!(batched.len(), 3);
    for (input, lb) in inputs.iter().zip(&batched) {
        let l1 = b1.score(std::slice::from_ref(input)).unwrap().remove(0);
        let mut max_diff = 0.0f32;
        for (a, b) in l1.data.iter().zip(&lb.data) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 1e-3, "b8 vs b1 diff {max_diff}");
    }
}
