//! Coordinator end-to-end over the native scorers: the serving path must
//! produce exactly the same perplexity as the direct evaluation harness.

use hisolo::compress::{CompressorConfig, Method};
use hisolo::coordinator::worker::{NativeCompressedScorer, NativeDenseScorer};
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::dataset::windows;
use hisolo::eval::perplexity::perplexity;
use hisolo::model::{CompressedModel, ModelConfig, Transformer};
use std::sync::Arc;
use std::time::Duration;

fn tiny_model() -> Arc<Transformer> {
    Arc::new(Transformer::random(
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        },
        5,
    ))
}

fn tiny_windows(model: &Transformer, count: usize) -> Vec<Vec<u32>> {
    let toks: Vec<u32> = (0..2000u32).map(|i| (i * 31 + i / 5) % 64).collect();
    windows(&toks, model.cfg.seq_len, count)
}

#[test]
fn coordinator_ppl_matches_direct_eval() {
    let model = tiny_model();
    let ws = tiny_windows(&model, 10);

    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            capacity: 64,
            ..BatcherConfig::default()
        },
    });
    coord.add_worker(
        Variant::Dense,
        NativeDenseScorer {
            model: model.clone(),
            max_batch: 4,
            kv: None,
        },
    );

    let resps = coord.submit_all(Variant::Dense, &ws).unwrap();
    assert!(resps.iter().all(|r| r.error.is_none()));
    let nll: f64 = resps.iter().map(|r| r.nll).sum();
    let toks: usize = resps.iter().map(|r| r.tokens).sum();
    let served_ppl = (nll / toks as f64).exp();

    let direct = perplexity(&ws, |t| model.forward(t));
    assert!(
        (served_ppl - direct.ppl).abs() < 1e-9,
        "served {served_ppl} vs direct {}",
        direct.ppl
    );
    coord.shutdown();
}

#[test]
fn dense_and_compressed_lanes_agree_at_high_rank() {
    let model = tiny_model();
    let ws = tiny_windows(&model, 6);
    let cm = Arc::new(CompressedModel::compress(
        model.clone(),
        Method::SHssRcm,
        CompressorConfig {
            rank: 16, // full off-diagonal rank at d=32 => near-lossless
            sparsity: 0.2,
            depth: 1,
            hss_rsvd: false,
            min_leaf: 4,
            ..Default::default()
        },
    ));

    let mut coord = Coordinator::new(CoordinatorConfig::default());
    coord.add_worker(
        Variant::Dense,
        NativeDenseScorer {
            model: model.clone(),
            max_batch: 4,
            kv: None,
        },
    );
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model: cm,
            max_batch: 4,
            kv: None,
        },
    );

    let dense = coord.submit_all(Variant::Dense, &ws).unwrap();
    let hss = coord.submit_all(Variant::Hss, &ws).unwrap();
    let ppl = |rs: &[hisolo::coordinator::ScoreResponse]| {
        let nll: f64 = rs.iter().map(|r| r.nll).sum();
        let toks: usize = rs.iter().map(|r| r.tokens).sum();
        (nll / toks as f64).exp()
    };
    let (pd, ph) = (ppl(&dense), ppl(&hss));
    assert!((pd - ph).abs() / pd < 0.02, "dense {pd} vs hss {ph}");
    coord.shutdown();
}

/// The bucketing satellite: under simulated mixed-length traffic, a
/// length-bucketed coordinator must (a) answer every request exactly once
/// — no drops, no duplicates — and (b) return per-request NLLs identical
/// to an unbucketed coordinator's, because a window's logits are
/// independent of which batch it rode in (pinned bit-for-bit at the
/// transformer level by `forward_batch_bit_matches_per_window_forward`).
#[test]
fn bucketed_serving_matches_unbucketed_and_drops_nothing() {
    let model = tiny_model();
    // ragged windows straddling the 4/8/16 bucket edges (scored lengths
    // 2..=16), repeated so polls mix lengths
    let toks: Vec<u32> = (0..4000u32).map(|i| (i * 31 + i / 5) % 64).collect();
    let mut ws: Vec<Vec<u32>> = Vec::new();
    for rep in 0..6usize {
        for len in [3usize, 5, 8, 9, 13, 17] {
            let start = (rep * 97 + len * 11) % (toks.len() - len - 1);
            ws.push(toks[start..start + len].to_vec());
        }
    }

    let mk = |edges: Vec<usize>| {
        let mut coord = Coordinator::new(CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                capacity: 256,
                bucket_edges: edges,
            },
        });
        coord.add_worker(
            Variant::Dense,
            NativeDenseScorer {
                model: model.clone(),
                max_batch: 8,
                kv: None,
            },
        );
        coord
    };

    let bucketed = mk(vec![4, 8, 16]);
    let plain = mk(Vec::new());
    let rb = bucketed.submit_all(Variant::Dense, &ws).unwrap();
    let rp = plain.submit_all(Variant::Dense, &ws).unwrap();

    // exactly one response per request, ids unique and order-preserved
    assert_eq!(rb.len(), ws.len());
    let mut ids: Vec<u64> = rb.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), ws.len(), "duplicate responses");
    for (b, p) in rb.iter().zip(&rp) {
        assert!(b.error.is_none() && p.error.is_none());
        assert_eq!(b.tokens, p.tokens);
        assert_eq!(b.nll, p.nll, "bucketing changed a request's NLL");
    }
    let completed = bucketed
        .metrics
        .completed
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(completed as usize, ws.len());
    // bucketed chunks are length-homogeneous: within a power-of-two
    // bucket, lengths differ by < 2×, so padding overhead is bounded
    // below 50% no matter how the polls landed (an unbucketed chunk
    // mixing t = 2 with t = 16 can waste far more)
    let po_b = bucketed.metrics.padding_overhead();
    assert!(po_b < 0.5, "bucketed pad overhead {po_b} >= 50%");
    // the summary surfaces the new gauges alongside resident bytes
    let s = bucketed.metrics.summary();
    assert!(s.contains("pad_overhead=") && s.contains("bucket_width="), "{s}");
    bucketed.shutdown();
    plain.shutdown();
}

#[test]
fn backpressure_surfaces_as_errors_not_hangs() {
    let model = tiny_model();
    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 2, // tiny queue
            ..BatcherConfig::default()
        },
    });
    coord.add_worker(
        Variant::Dense,
        NativeDenseScorer {
            model: model.clone(),
            max_batch: 2,
            kv: None,
        },
    );
    let ws = tiny_windows(&model, 64);
    // fire-hose submits; some may be rejected, none may hang
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for w in &ws {
        match coord.submit(Variant::Dense, w.clone()) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none());
    }
    // metrics are consistent
    let m = &coord.metrics;
    let sub = m.submitted.load(std::sync::atomic::Ordering::Relaxed);
    let rej = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(rej as usize, rejected);
    assert_eq!(sub as usize, ws.len());
    coord.shutdown();
}
