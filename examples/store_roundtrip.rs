//! Store round-trip demo: compress a model's q/k/v once, persist the HSB1
//! artifact store, cold-start a serving coordinator from disk (no
//! recompression), then hot-swap to a second variant under live traffic.
//!
//!     cargo run --release --example store_roundtrip

use hisolo::compress::{CompressorConfig, Method};
use hisolo::coordinator::worker::NativeCompressedScorer;
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::dataset::windows;
use hisolo::model::{CompressedModel, ModelConfig, Transformer};
use hisolo::store::ModelStore;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let base = Arc::new(Transformer::random(ModelConfig::default(), 42));
    let store = ModelStore::open(std::env::temp_dir().join("hisolo_store_demo"));

    // 1. compress twice (expensive) and persist both variants (cheap)
    for (variant, method, rank) in [
        ("shss-rcm-r32", Method::SHssRcm, 32),
        ("shss-rcm-r16", Method::SHssRcm, 16),
    ] {
        let cfg = CompressorConfig {
            rank,
            sparsity: 0.3,
            depth: 3,
            ..Default::default()
        };
        let t0 = Instant::now();
        let cm = CompressedModel::compress(base.clone(), method, cfg);
        let compress_s = t0.elapsed().as_secs_f64();
        let path = store.save_model(variant, &cm)?;
        println!(
            "{variant}: compressed in {compress_s:.2}s, {} bytes on disk ({:.3}x of dense qkv) -> {}",
            store.variant_bytes(variant),
            cm.qkv_raw_bytes() as f64 / cm.qkv_dense_bytes() as f64,
            path.display()
        );
    }

    // 2. cold start: load without recompression and serve
    let t0 = Instant::now();
    let first = Arc::new(store.load_model("shss-rcm-r32", base.clone())?);
    println!(
        "\ncold start from store: {:.1} ms (vs seconds of recompression)",
        t0.elapsed().as_secs_f64() * 1e3
    );

    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            capacity: 1024,
            ..BatcherConfig::default()
        },
    });
    coord.add_worker(
        Variant::Hss,
        NativeCompressedScorer {
            model: first,
            max_batch: 8,
            kv: None,
        },
    );

    let toks: Vec<u32> = (0..20_000u32).map(|i| (i * 1103515245 + 12345) % 256).collect();
    let ws = windows(&toks, base.cfg.seq_len, 24);

    let before = coord.submit_all(Variant::Hss, &ws)?;
    report("rank-32 variant", &before);

    // 3. hot-swap to the rank-16 variant while the lane stays registered;
    //    requests submitted during the swap are served by whichever scorer
    //    owns the batch — never a torn mix
    let swap_store = ModelStore::open(store.dir().to_path_buf());
    let swap_base = base.clone();
    let ticket = coord.swap_variant(Variant::Hss, move || {
        let model = Arc::new(swap_store.load_model("shss-rcm-r16", swap_base.clone())?);
        Ok(NativeCompressedScorer {
            model,
            max_batch: 8,
            kv: None,
        })
    })?;
    ticket.wait(Duration::from_secs(10))?;
    println!("\nhot-swapped to rank-16 variant (no dropped requests)");

    let after = coord.submit_all(Variant::Hss, &ws)?;
    report("rank-16 variant", &after);

    println!("\nmetrics: {}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}

fn report(label: &str, resps: &[hisolo::coordinator::ScoreResponse]) {
    let nll: f64 = resps.iter().map(|r| r.nll).sum();
    let toks: usize = resps.iter().map(|r| r.tokens).sum();
    let errors = resps.iter().filter(|r| r.error.is_some()).count();
    println!(
        "{label}: {} responses, {errors} errors, ppl {:.4}",
        resps.len(),
        (nll / toks as f64).exp()
    );
}
