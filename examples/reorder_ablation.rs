//! RCM reordering ablation (paper §5.4): what does the permutation actually
//! buy? Measures bandwidth / diagonal mass concentration before and after
//! RCM on real trained projections, and the resulting HSS reconstruction
//! error with and without reordering.
//!
//!     make artifacts && cargo run --release --example reorder_ablation

use hisolo::hss::{build, HssOptions};
use hisolo::linalg::norms::rel_fro_error;
use hisolo::model::{Transformer, WeightFile};
use hisolo::runtime::ArtifactDir;
use hisolo::sparse::bandwidth::{bandwidth, mass_within_band};
use hisolo::sparse::graph::Graph;
use hisolo::sparse::{rcm, top_p_extract};
use hisolo::util::timer::Table;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_path();
    let artifacts = ArtifactDir::load(&dir)?;
    let weights = WeightFile::load(&dir.join("model.hwt"))?;
    let model = Transformer::from_weights(&weights, artifacts.model_config)?;

    let mut t = Table::new(&[
        "projection",
        "bandwidth before",
        "bandwidth after",
        "mass@band16 before",
        "mass@band16 after",
        "hss err",
        "hss-rcm err",
    ]);

    for (name, w) in model.qkv_projections().into_iter().take(6) {
        let a = w.transpose();
        // isolate the residual the HSS stage actually sees (sp10)
        let (_s, resid) = top_p_extract(&a, 0.10);
        let g = Graph::from_pattern(&resid, 0.90);
        let p = rcm(&g);
        let reordered = resid.permute_sym(p.indices());

        // pattern bandwidth at the same quantile threshold
        let thresh = hisolo::sparse::graph::magnitude_quantile(&resid, 0.90);
        let bw_before = bandwidth_at(&resid, thresh);
        let bw_after = bandwidth_at(&reordered, thresh);

        let mk = |use_rcm| HssOptions {
            rank: 16,
            sparsity: 0.10,
            depth: 3,
            use_rcm,
            ..Default::default()
        };
        let err_plain = rel_fro_error(&build(&a, &mk(false)).reconstruct(), &a);
        let err_rcm = rel_fro_error(&build(&a, &mk(true)).reconstruct(), &a);

        t.row(&[
            name,
            bw_before.to_string(),
            bw_after.to_string(),
            format!("{:.3}", mass_within_band(&resid, 16)),
            format!("{:.3}", mass_within_band(&reordered, 16)),
            format!("{err_plain:.4}"),
            format!("{err_rcm:.4}"),
        ]);
    }
    t.print();
    println!(
        "\npaper §5.4: RCM gives a slight but consistent gain; the reordered\n\
         residual concentrates large entries near the diagonal, shrinking\n\
         the numerical rank of the off-diagonal HSS blocks."
    );
    Ok(())
}

fn bandwidth_at(m: &hisolo::linalg::Matrix, thresh: f32) -> usize {
    bandwidth(m, thresh)
}
