//! End-to-end driver (DESIGN.md §5): load the trained model from
//! `artifacts/`, compress its q/k/v projections with every Fig-3 method,
//! evaluate perplexity on the held-out corpus through the native forward
//! pass, and cross-check one batch against the AOT HLO executable through
//! the PJRT runtime. This is the run recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example compress_model

use hisolo::compress::{CompressorConfig, Method};
use hisolo::data::corpus::Corpus;
use hisolo::data::dataset::windows;
use hisolo::eval::sweep::eval_point;
use hisolo::model::{Transformer, WeightFile};
use hisolo::runtime::{ArtifactDir, Runtime};
use hisolo::util::timer::Table;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_path();
    let artifacts = ArtifactDir::load(&dir)?;
    let weights = WeightFile::load(&dir.join("model.hwt"))?;
    let model = Arc::new(Transformer::from_weights(&weights, artifacts.model_config)?);
    let corpus = Corpus::load(&dir.join("corpus_test.txt"))?;
    let ws = windows(&corpus.tokens, artifacts.model_config.seq_len, 24);
    let threads = std::thread::available_parallelism()?.get().min(16);

    println!(
        "model: {:?} ({} params, {} in q/k/v)",
        artifacts.model_config,
        artifacts.model_config.param_count(),
        artifacts.model_config.qkv_params()
    );
    println!("eval: {} windows x {} tokens\n", ws.len(), artifacts.model_config.seq_len);

    // headline operating point: sp30, outer rank d/8 = 32, depth 3
    let cfg = CompressorConfig {
        rank: 32,
        sparsity: 0.3,
        depth: 3,
        ..Default::default()
    };

    let mut table = Table::new(&[
        "method", "ppl", "qkv ratio", "model ratio", "rel err", "compress s",
    ]);
    let mut dense_ppl = 0.0;
    for m in Method::FIG3 {
        let p = eval_point(&model, m, cfg, &ws, threads);
        if m == Method::Dense {
            dense_ppl = p.ppl;
        }
        table.row(&[
            m.paper_label().to_string(),
            format!("{:.4}", p.ppl),
            format!("{:.3}", p.qkv_ratio()),
            format!("{:.3}", p.model_ratio),
            format!("{:.4}", p.mean_rel_error),
            format!("{:.2}", p.compress_secs),
        ]);
        println!("{} done (ppl {:.4})", m.paper_label(), p.ppl);
    }
    println!();
    table.print();
    println!("\n(dense baseline ppl {dense_ppl:.4}; paper reports 1.64 for sHSS-RCM @ sp30/r512 on LLaMA-7B)");

    // --- cross-check: native forward vs the AOT PJRT executable ------------
    println!("\ncross-check vs AOT HLO executable (PJRT CPU):");
    let rt = Runtime::cpu()?;
    let loaded = rt.load_model(&artifacts, "model_dense_b1", &[&weights])?;
    let input = ws[0][..artifacts.model_config.seq_len].to_vec();
    let hlo_logits = loaded.score(&[input.clone()])?.remove(0);
    let native_logits = model.forward(&input);
    let max_diff = hlo_logits
        .data
        .iter()
        .zip(&native_logits.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logit diff| native vs HLO: {max_diff:.5}");
    anyhow::ensure!(max_diff < 3e-2, "HLO/native mismatch");
    println!("OK — all layers compose (L1 pallas kernels -> L2 jax graph -> L3 rust runtime)");
    Ok(())
}
