//! Quickstart: compress a single trained-like matrix with every method and
//! compare error vs storage — the paper's core trade-off in 30 lines.
//!
//!     cargo run --release --example quickstart

use hisolo::compress::{Compressor, CompressorConfig, Method};
use hisolo::data::synthetic;
use hisolo::util::timer::Table;

fn main() {
    // a 256x256 matrix with the structure trained projections show:
    // low-rank bulk + a few large-magnitude "spikes"
    let w = synthetic::trained_like(256, 42);

    let cfg = CompressorConfig {
        rank: 32,      // outer rank (d/8, scaling the paper's 512@4096)
        sparsity: 0.3, // sp30
        depth: 3,      // paper's Algorithm 1
        ..Default::default()
    };
    let comp = Compressor::new(cfg);

    let mut table = Table::new(&["method", "rel error", "storage ratio", "params"]);
    for m in Method::ALL {
        let c = comp.compress(&w, m);
        table.row(&[
            m.paper_label().to_string(),
            format!("{:.4}", c.rel_error(&w)),
            format!("{:.3}", c.storage_ratio()),
            c.params().to_string(),
        ]);
    }
    table.print();

    // the compressed matvec is a drop-in replacement for y = W x
    let c = comp.compress(&w, Method::SHssRcm);
    let x: Vec<f32> = (0..256).map(|i| (i as f32 * 0.1).sin()).collect();
    let y = c.matvec(&x);
    let y_exact = {
        let mut out = vec![0.0; 256];
        w.matvec_into(&x, &mut out);
        out
    };
    let err: f32 = y
        .iter()
        .zip(&y_exact)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("\nsHSS-RCM matvec max abs deviation from dense: {err:.4}");
}
