//! Serving demo: start the coordinator with dense + sHSS PJRT executables,
//! fire batched scoring requests, and report latency/throughput — the
//! paper's "compressed models retain full inference speed" claim, measured.
//!
//!     make artifacts && cargo run --release --example serve_requests

use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::corpus::Corpus;
use hisolo::data::dataset::windows;
use hisolo::model::WeightFile;
use hisolo::runtime::{ArtifactDir, Runtime};
use hisolo::util::timer::Table;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_path();
    let artifacts = ArtifactDir::load(&dir)?;
    let seq = artifacts.model_config.seq_len;

    let mut coord = Coordinator::new(CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
            ..BatcherConfig::default()
        },
    });

    // workers construct their own PJRT client (the xla client is !Send)
    for (variant, exe) in [
        (Variant::Dense, "model_dense_b8"),
        (Variant::Hss, "model_hss_b8"),
    ] {
        let dir = dir.clone();
        coord.add_worker_factory(variant, move || {
            let a = ArtifactDir::load(&dir)?;
            let weights = WeightFile::load(&dir.join("model.hwt"))?;
            let rt = Runtime::cpu()?;
            println!("[worker {}] compiling {exe} on {}", variant.name(), rt.platform());
            if exe.contains("hss") {
                let ops = WeightFile::load(&dir.join("hss_operands.hwt"))?;
                rt.load_model(&a, exe, &[&weights, &ops])
            } else {
                rt.load_model(&a, exe, &[&weights])
            }
        });
    }

    let corpus = Corpus::load(&dir.join("corpus_test.txt"))?;
    let ws = windows(&corpus.tokens, seq, 48);
    println!("submitting {} requests per variant...\n", ws.len());

    let mut table = Table::new(&[
        "variant", "ppl", "req/s", "p50 ms", "p95 ms", "mean batch",
    ]);
    for variant in [Variant::Dense, Variant::Hss] {
        let t0 = Instant::now();
        let resps = coord.submit_all(variant, &ws)?;
        let wall = t0.elapsed().as_secs_f64();
        if let Some(e) = resps.iter().find_map(|r| r.error.clone()) {
            anyhow::bail!("variant {}: {e}", variant.name());
        }
        let nll: f64 = resps.iter().map(|r| r.nll).sum();
        let toks: usize = resps.iter().map(|r| r.tokens).sum();
        let mut lat: Vec<u64> = resps.iter().map(|r| r.latency_us).collect();
        lat.sort_unstable();
        let mean_batch =
            resps.iter().map(|r| r.batch_size).sum::<usize>() as f64 / resps.len() as f64;
        table.row(&[
            variant.name().to_string(),
            format!("{:.4}", (nll / toks as f64).exp()),
            format!("{:.1}", resps.len() as f64 / wall),
            format!("{:.1}", lat[lat.len() / 2] as f64 / 1e3),
            format!("{:.1}", lat[lat.len() * 95 / 100] as f64 / 1e3),
            format!("{mean_batch:.2}"),
        ]);
    }
    table.print();
    println!("\ncoordinator metrics: {}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}
